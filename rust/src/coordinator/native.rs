//! `NativeEngine`: the artifact-free serving backend, driven by a
//! **unified chunked-prefill scheduler**.
//!
//! Where the XLA [`super::engine::Engine`] must run two-phase ticks
//! (inline whole-prompt prefill at admission, then bucketed decode
//! rounds — its AOT graphs cannot pause mid-prompt), this engine runs
//! ONE step-loop: every tick assembles a single mixed work plan
//! ([`batcher::plan_tick`]) under a token budget
//! (`max_tokens_per_tick`) that packs
//!
//! * all decode lanes (1 token each — inter-token latency is the
//!   protected quantity), batched into minimum-padding bucket rounds
//!   exactly as before, and
//! * prefill **chunks**: every in-flight prompt advances by up to
//!   `prefill_chunk` tokens, all scheduled prompts together as one
//!   (B, T) batched execution ([`StepModel::prefill_batch_into`] —
//!   ragged chunks padded to the chunk grid, projections as one
//!   B·T_max-row int8 GEMM, conv/scan per lane over carried state).
//!
//! A 2k-token prompt therefore no longer freezes every live lane for
//! a whole prompt's worth of compute: it advances `prefill_chunk`
//! tokens per tick while decode keeps ticking (paper §1 / Table 1:
//! bounded generation latency under request-intensive load). SSMs are
//! uniquely suited to this — the recurrent state is constant-size, so
//! a prefill pauses at any token boundary for free, and chunking is
//! **bit-exact** (`rust/tests/chunked_prefill.rs`).
//!
//! Cold, warm (prefix-cache hit) and resumed prefills all flow
//! through the same chunk queue: admission probes the trie, restores
//! the longest cached prefix into the request's pool slot and enqueues
//! the *suffix* as an ordinary partially-consumed prompt
//! ([`Phase::Prefilling`]); a full-prompt hit samples from the cached
//! logits row and joins decode with zero model execution. Chunk ends
//! snap to the `snapshot_stride` grid, so chunked prefills emit the
//! identical nested-prefix snapshots the old whole-prompt path did.
//!
//! Hot-path properties (PR 2–5):
//! * decode rounds execute out of per-round reusable
//!   [`StepScratch`]es — no per-step allocation in the model after
//!   warmup (asserted in `rust/tests/zero_alloc.rs`, which also holds
//!   the chunked (B, T) prefill body to the zero-alloc standard);
//! * quantized models get an i8 conv-window pool
//!   ([`SsmStatePool::with_quantized_conv`], quarter the conv state
//!   bytes);
//! * `threads > 1` parallelizes decode across groups (or lanes of a
//!   lone group) — **bit-identical** to `threads = 1`;
//! * the int8 hot paths run on the [`Kernels`] SIMD dispatch
//!   (`NativeEngineConfig::kernel_backend`) — bit-identical across
//!   backends;
//! * every request samples from its **own** RNG stream
//!   ([`LiveRequest::rng`]): chunk size, token budget, cache hits and
//!   thread count can move *when* a request's tokens are produced,
//!   never *which* tokens — the scheduler is latency policy, not
//!   sampling policy.

use std::collections::VecDeque;

use anyhow::Result;

use crate::cache::{CacheStats, PrefixCache, PrefixCacheConfig, Snapshot};
use crate::coordinator::batcher;
use crate::coordinator::engine::DEFAULT_SAMPLER_SEED;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{LiveRequest, Phase, Request, Response};
use crate::coordinator::sampler;
use crate::coordinator::state::SsmStatePool;
use crate::data::BOS;
use crate::quant::{KernelBackend, Kernels};
use crate::ssm::{MambaState, StepModel, StepScratch};

#[derive(Debug, Clone)]
pub struct NativeEngineConfig {
    /// state-pool capacity (max concurrent requests)
    pub capacity: usize,
    /// admissions per tick into the chunk queue (backpressure on the
    /// scheduler's bookkeeping; prompt *work* is paced by
    /// `prefill_chunk` / `max_tokens_per_tick`, not by this)
    pub max_prefills_per_tick: usize,
    /// decode-round lane buckets (ascending). The native backend can
    /// run any batch size, but bucketing keeps the scheduling identical
    /// to the AOT deployment shape so the two backends are comparable.
    pub decode_buckets: Vec<usize>,
    /// decode worker threads. 1 (default) is the fully sequential
    /// path; >1 runs decode rounds on at most `threads` scoped workers
    /// (and lane-splits a lone round) — output tokens are bit-identical
    /// either way.
    pub threads: usize,
    /// engine-level sampler seed; each request derives its own RNG
    /// stream from (this, request id, `SamplingParams::seed`), so
    /// scheduling order never perturbs sampling
    pub sampler_seed: u64,
    /// int8 kernel backend for the model hot paths. `None` (default)
    /// auto-selects once per process (`QUAMBA_KERNELS` env override,
    /// else runtime detection); `Some(b)` forces backend `b` for this
    /// engine — panics at construction if the machine cannot run it.
    /// Every backend yields **bit-identical** tokens (tested).
    pub kernel_backend: Option<KernelBackend>,
    /// prefix-cache byte budget; 0 (default) disables the cache. SSM
    /// snapshots are constant-size, so this is simply
    /// budget / (state bytes + overhead) cacheable prefixes, whatever
    /// their token lengths.
    pub cache_bytes: usize,
    /// with the cache on, also snapshot every `snapshot_stride` prompt
    /// tokens (nested-prefix reuse); 0 = end-of-prompt snapshots only.
    /// Chunk boundaries snap to this grid so chunked prefills emit the
    /// same snapshot keys as whole-prompt prefills.
    pub snapshot_stride: usize,
    /// max prompt tokens one in-flight prefill advances per tick;
    /// 0 (default) = unchunked (a prompt completes in the tick it is
    /// scheduled). Small values bound the inter-token latency decode
    /// lanes observe while long prompts stream in — chunking moves
    /// latency, **never tokens** (`rust/tests/chunked_prefill.rs`).
    pub prefill_chunk: usize,
    /// per-tick token budget across decode lanes (1 each) + prefill
    /// chunks; 0 (default) = unlimited. When decode alone saturates
    /// the budget, the oldest prefill still advances 1 token/tick
    /// (see [`batcher::plan_tick`]).
    pub max_tokens_per_tick: usize,
}

impl Default for NativeEngineConfig {
    fn default() -> Self {
        NativeEngineConfig {
            capacity: 32,
            max_prefills_per_tick: 2,
            decode_buckets: vec![1, 2, 4, 8],
            threads: 1,
            sampler_seed: DEFAULT_SAMPLER_SEED,
            kernel_backend: None,
            cache_bytes: 0,
            snapshot_stride: 0,
            prefill_chunk: 0,
            max_tokens_per_tick: 0,
        }
    }
}

/// Reusable per-round workspace: the model scratch plus its logits
/// output buffer. One per concurrent decode group, reused every tick.
struct RoundScratch {
    scratch: StepScratch,
    logits: Vec<f32>,
}

impl RoundScratch {
    fn new(kernels: Kernels) -> RoundScratch {
        RoundScratch { scratch: StepScratch::with_kernels(1, kernels), logits: Vec::new() }
    }
}

/// One decode round's gathered inputs/state (built per tick).
struct RoundIo {
    slots: Vec<usize>,
    toks: Vec<u16>,
    state: MambaState,
    /// model execution time for this round (recorded into
    /// `Metrics::decode_step_ms`, one sample per round — same
    /// semantics as the XLA engine)
    step_ms: f64,
}

/// One prefilling lane's allotment for this tick: advance
/// `live[live_i]` from `next` up to `target` (both prompt-token
/// indices), possibly across several stride-aligned sub-rounds.
struct LanePlan {
    live_i: usize,
    next: usize,
    target: usize,
}

pub struct NativeEngine {
    pub cfg: NativeEngineConfig,
    model: Box<dyn StepModel + Send + Sync>,
    pool: SsmStatePool,
    queue: VecDeque<Request>,
    live: Vec<LiveRequest>,
    done: Vec<Response>,
    pub metrics: Metrics,
    vocab: usize,
    scratches: Vec<RoundScratch>,
    kernels: Kernels,
    /// prefix-sharing snapshot cache (`cfg.cache_bytes > 0`)
    cache: Option<PrefixCache>,
    /// monotonic admission counter — the chunk queue's FIFO key
    /// (`LiveRequest::admitted_seq`); the live vec itself is reordered
    /// by harvest's `swap_remove`
    next_admission_seq: u64,
}

impl NativeEngine {
    pub fn new(model: Box<dyn StepModel + Send + Sync>, cfg: NativeEngineConfig) -> NativeEngine {
        assert!(!cfg.decode_buckets.is_empty(), "need at least one decode bucket");
        let kernels = match cfg.kernel_backend {
            Some(b) => Kernels::for_backend(b),
            None => Kernels::auto(),
        };
        let t = model.tier();
        let mut pool =
            SsmStatePool::with_dims(t.n_layer, t.d_inner, t.d_conv, t.d_state, cfg.capacity);
        if model.quantized_conv_state() {
            pool = pool.with_quantized_conv();
        }
        let vocab = t.vocab;
        let cache = (cfg.cache_bytes > 0).then(|| {
            PrefixCache::new(PrefixCacheConfig {
                capacity_bytes: cfg.cache_bytes,
                snapshot_stride: cfg.snapshot_stride,
            })
        });
        NativeEngine {
            pool,
            queue: VecDeque::new(),
            live: Vec::new(),
            done: Vec::new(),
            metrics: Metrics::new(),
            vocab,
            scratches: vec![RoundScratch::new(kernels)],
            kernels,
            cache,
            next_admission_seq: 0,
            model,
            cfg,
        }
    }

    /// Prefix-cache counters; `None` when serving with the cache off.
    pub fn cache_stats(&self) -> Option<CacheStats> {
        self.cache.as_ref().map(|c| c.stats())
    }

    pub fn decode_buckets(&self) -> &[usize] {
        &self.cfg.decode_buckets
    }

    /// The int8 kernel dispatch this engine executes with (for logging
    /// / bench labeling).
    pub fn kernels(&self) -> Kernels {
        self.kernels
    }

    pub fn submit(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    pub fn n_queued(&self) -> usize {
        self.queue.len()
    }

    pub fn n_live(&self) -> usize {
        self.live.len()
    }

    /// Live requests still consuming their prompt (the chunk queue).
    pub fn n_prefilling(&self) -> usize {
        self.live.iter().filter(|lr| lr.prefill_remaining() > 0).count()
    }

    pub fn state_bytes_per_request(&self) -> usize {
        self.pool.bytes_per_request()
    }

    /// Tokens generated so far (live requests + completed).
    pub fn tokens_generated(&self) -> usize {
        self.live.iter().map(|lr| lr.generated.len()).sum::<usize>()
            + self.metrics.tokens_out as usize
    }

    /// Run one unified scheduler tick:
    /// 1. **admission** — pop queued requests into the live set (pool
    ///    capacity gates), probing the prefix cache: hits restore the
    ///    cached slab and enqueue only the suffix; full-prompt hits
    ///    join decode immediately;
    /// 2. **plan** — one mixed decode+prefill plan under the token
    ///    budget ([`batcher::plan_tick`]);
    /// 3. **decode rounds** — every decoding lane advances 1 token
    ///    (bucketed, minimum padding, optionally threaded);
    /// 4. **prefill chunk batch** — all scheduled prompts advance up
    ///    to `prefill_chunk` tokens as one (B, T) batched execution;
    ///    prompts that finish sample their first token and flip to
    ///    [`Phase::Decoding`];
    /// 5. **harvest** — finished requests become [`Response`]s.
    ///
    /// Returns finished responses (also retained for `take_done`).
    /// Result-typed for interface parity with
    /// [`super::engine::Engine::step`]; the native path cannot fail.
    pub fn step(&mut self) -> Result<Vec<Response>> {
        self.admit();
        let dec_idx: Vec<usize> = (0..self.live.len())
            .filter(|&i| self.live[i].phase == Phase::Decoding)
            .collect();
        let mut pf_idx: Vec<usize> = (0..self.live.len())
            .filter(|&i| matches!(self.live[i].phase, Phase::Prefilling { .. }))
            .collect();
        // true FIFO over admissions: harvest's swap_remove scrambles
        // live-vec order, so the budget (and the minimum-progress
        // guarantee) must key on admission order, not position
        pf_idx.sort_by_key(|&i| self.live[i].admitted_seq);
        let remaining: Vec<usize> =
            pf_idx.iter().map(|&i| self.live[i].prefill_remaining()).collect();
        let plan = batcher::plan_tick(
            dec_idx.len(),
            &remaining,
            &self.cfg.decode_buckets,
            self.cfg.prefill_chunk,
            self.cfg.max_tokens_per_tick,
        );
        // decode first: the latency-critical lanes never wait behind
        // this tick's prefill work
        if !dec_idx.is_empty() {
            self.decode_tick(&dec_idx, &plan.decode_rounds);
        }
        if !plan.chunks.is_empty() {
            self.prefill_tick(&pf_idx, &plan.chunks);
        }
        let mut finished = Vec::new();
        let mut i = 0;
        while i < self.live.len() {
            if self.live[i].done() {
                let lr = self.live.swap_remove(i);
                self.pool.release(lr.state_slot);
                let resp = lr.into_response();
                self.metrics.record_response(
                    resp.ttft_ms,
                    resp.tpot_ms,
                    resp.ttlt_ms,
                    resp.tokens.len(),
                    &resp.itl_ms,
                );
                finished.push(resp);
            } else {
                i += 1;
            }
        }
        self.done.extend(finished.iter().cloned());
        Ok(finished)
    }

    /// Drive until everything queued + live has finished.
    pub fn run_to_completion(&mut self) -> Result<Vec<Response>> {
        while !self.queue.is_empty() || !self.live.is_empty() {
            self.step()?;
        }
        Ok(std::mem::take(&mut self.done))
    }

    pub fn take_done(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.done)
    }

    /// Admission: allocate a pool slot, probe the prefix cache, and
    /// enqueue whatever prompt suffix is left as chunked-prefill work.
    /// No model execution happens here — that is the point: a burst of
    /// long prompts costs this tick only a trie probe and a slab
    /// restore per request, and their *compute* is paced by the
    /// planner across the following ticks.
    fn admit(&mut self) {
        for _ in 0..self.cfg.max_prefills_per_tick {
            if self.queue.is_empty() || self.pool.in_use() >= self.pool.capacity() {
                break;
            }
            let req = self.queue.pop_front().unwrap();
            let slot = self.pool.alloc().expect("state pool exhausted (checked above)");
            let use_cache = self.cache.is_some() && !req.params.no_cache;
            let mut lr = LiveRequest::new(req, slot, self.cfg.sampler_seed);
            lr.admitted_seq = self.next_admission_seq;
            self.next_admission_seq += 1;
            let hit =
                if use_cache { self.cache.as_mut().unwrap().lookup(&lr.prompt) } else { None };
            if let Some(h) = hit {
                if let Some(row) = h.logits_row {
                    // full-prompt hit: restore the end-of-prompt state
                    // and sample from the cached row — zero model
                    // execution, straight into the decode phase
                    self.pool.write(slot, h.slab);
                    let tok = sampler::sample_row(&mut lr.rng, &row, self.vocab, &lr.req.params);
                    lr.generated.push(tok);
                    lr.phase = Phase::Decoding;
                    lr.prefill_done = Some(std::time::Instant::now());
                    lr.last_token = lr.prefill_done;
                } else if h.len < lr.prompt.len() {
                    // partial hit: the restored prefix is this model's
                    // deterministic state for those tokens, so the
                    // suffix enters the chunk queue like any cold
                    // prompt admitted mid-prefill — one scheduler path
                    self.pool.write(slot, h.slab);
                    lr.phase = Phase::Prefilling { next: h.len };
                }
                // else: a full-length hit without a logits row should
                // be unreachable (lookup filters those); fall through
                // to a cold prefill over the freshly-zeroed slab
                // rather than panicking the serving loop
            }
            self.live.push(lr);
        }
        // one stats sync per tick — the counters are cumulative, so
        // only the post-admission snapshot matters
        if let Some(c) = &self.cache {
            self.metrics.record_cache_stats(c.stats());
        }
    }

    /// One decode pass over the decoding lanes `dec` (indices into
    /// `self.live`), following the plan's bucket rounds.
    fn decode_tick(&mut self, dec: &[usize], rounds: &[usize]) {
        let groups = batcher::assign(dec.len(), rounds);
        // gather phase: pack every group's lanes/tokens/state
        let mut io: Vec<RoundIo> = Vec::with_capacity(groups.len());
        for (gi, group) in groups.iter().enumerate() {
            let b = rounds[gi];
            self.metrics.record_round(b, group.len());
            let slots: Vec<usize> =
                group.iter().map(|&p| self.live[dec[p]].state_slot).collect();
            let mut toks = vec![BOS; b]; // padded lanes run a throwaway BOS
            for (bi, &p) in group.iter().enumerate() {
                toks[bi] = self.live[dec[p]].next_input_token();
            }
            let state = self.pool.gather_state(self.model.tier(), &slots, b);
            io.push(RoundIo { slots, toks, state, step_ms: 0.0 });
        }
        while self.scratches.len() < io.len() {
            self.scratches.push(RoundScratch::new(self.kernels));
        }
        // execute phase
        let model = &*self.model;
        let scratches = &mut self.scratches;
        let threads = self.cfg.threads.max(1);
        if threads > 1 && io.len() > 1 {
            // group-level parallelism, capped at `threads` scoped
            // workers: each worker runs a contiguous chunk of rounds
            // sequentially (within-step threading off — the workers
            // already cover the cores). Commit stays in group order
            // below, so tokens match the sequential schedule exactly.
            let per = io.len().div_ceil(threads);
            std::thread::scope(|sc| {
                for (rs, wss) in io.chunks_mut(per).zip(scratches.chunks_mut(per)) {
                    sc.spawn(move || {
                        for (r, ws) in rs.iter_mut().zip(wss.iter_mut()) {
                            ws.scratch.threads = 1;
                            let t0 = std::time::Instant::now();
                            model.step_into(
                                &r.toks,
                                &mut r.state,
                                &mut ws.scratch,
                                &mut ws.logits,
                            );
                            r.step_ms = t0.elapsed().as_secs_f64() * 1e3;
                        }
                    });
                }
            });
        } else {
            for (r, ws) in io.iter_mut().zip(scratches.iter_mut()) {
                ws.scratch.threads = threads;
                let t0 = std::time::Instant::now();
                model.step_into(&r.toks, &mut r.state, &mut ws.scratch, &mut ws.logits);
                r.step_ms = t0.elapsed().as_secs_f64() * 1e3;
            }
        }
        // one latency sample per round, in deterministic group order
        // (same metric semantics as the XLA engine's decode_round)
        for r in &io {
            self.metrics.decode_step_ms.record(r.step_ms);
        }
        // commit phase (deterministic order): scatter states, sample
        let v = self.vocab;
        for (gi, r) in io.into_iter().enumerate() {
            let RoundIo { slots, state, .. } = r;
            // only live slots are scattered back; padded-lane outputs drop
            self.pool.scatter_state(&slots, state);
            let logits = &self.scratches[gi].logits;
            for (bi, &p) in groups[gi].iter().enumerate() {
                let row = &logits[bi * v..(bi + 1) * v];
                let lr = &mut self.live[dec[p]];
                let tok = sampler::sample_row(&mut lr.rng, row, v, &lr.req.params);
                lr.generated.push(tok);
                let now = std::time::Instant::now();
                if let Some(last) = lr.last_token {
                    lr.decode_ms.push((now - last).as_secs_f64() * 1e3);
                }
                lr.last_token = Some(now);
            }
        }
    }

    /// The tick's (B, T) batched prefill work over the scheduled
    /// chunks (`pf` maps planner positions to `self.live` indices).
    /// Every lane consumes its WHOLE allotment (`ca.tokens`, capped at
    /// prompt end) this tick — the planner's token budget is spent
    /// exactly, and `prefill_chunk = 0` keeps its "prompt completes in
    /// the tick it is scheduled" meaning with the cache on. The stride
    /// grid shapes *sub-rounds*, not the amount of work: each
    /// sub-round advances all unfinished lanes to their next global
    /// stride cut (or target / prompt end) as one batched execution,
    /// inserting interior/end-of-prompt snapshots at exactly the keys
    /// the old inline whole-prompt path used. With the cache off (or
    /// `snapshot_stride = 0`) this collapses to a single sub-round.
    fn prefill_tick(&mut self, pf: &[usize], chunks: &[batcher::ChunkAssignment]) {
        let stride = self.cache.as_ref().map_or(0, |c| c.config().snapshot_stride);
        let mut lanes: Vec<LanePlan> = Vec::with_capacity(chunks.len());
        for ca in chunks {
            let live_i = pf[ca.idx];
            let lr = &self.live[live_i];
            let next = match lr.phase {
                Phase::Prefilling { next } => next,
                Phase::Decoding => unreachable!("planner only schedules prefilling requests"),
            };
            let target = lr.prompt.len().min(next + ca.tokens);
            debug_assert!(target > next, "planner scheduled an empty chunk");
            lanes.push(LanePlan { live_i, next, target });
        }
        // the chunk batch gets a throwaway scratch: its buffers are
        // sized by B·T_chunk rows, and parking them in the engine's
        // round workspaces would pin O(B·T·vocab) heap for the whole
        // session (decode only ever needs B rows). The model itself is
        // allocation-free inside the call (tests/zero_alloc.rs).
        let mut scratch = StepScratch::with_kernels(1, self.kernels);
        let mut logits: Vec<f32> = Vec::new();
        let v = self.vocab;
        while lanes.iter().any(|l| l.next < l.target) {
            // this sub-round's spans: (index into `lanes`, start, end),
            // ends snapped to the global stride grid so interior
            // snapshots land on one aligned cut set whatever chunk
            // size or resume point a request came in with (cutting
            // never changes bits, only snapshot placement)
            let mut round: Vec<(usize, usize, usize)> = Vec::new();
            for (i, l) in lanes.iter().enumerate() {
                if l.next >= l.target {
                    continue;
                }
                let mut end = l.target;
                if stride > 0 && !self.live[l.live_i].req.params.no_cache {
                    end = end.min((l.next / stride + 1) * stride);
                }
                round.push((i, l.next, end));
            }
            let b = round.len();
            let slots: Vec<usize> = round
                .iter()
                .map(|&(i, _, _)| self.live[lanes[i].live_i].state_slot)
                .collect();
            let mut state = self.pool.gather_state(self.model.tier(), &slots, b);
            let t_max = round.iter().map(|&(_, s, e)| e - s).max().unwrap();
            {
                let live = &self.live;
                let chunk_slices: Vec<&[u16]> = round
                    .iter()
                    .map(|&(i, s, e)| &live[lanes[i].live_i].prompt[s..e])
                    .collect();
                let t0 = std::time::Instant::now();
                self.model.prefill_batch_into(
                    &chunk_slices,
                    &mut state,
                    &mut scratch,
                    &mut logits,
                );
                // prefill_ms samples per batched sub-round (the unit
                // the scheduler actually executes), like decode_step_ms
                self.metrics.prefill_ms.record(t0.elapsed().as_secs_f64() * 1e3);
            }
            self.pool.scatter_state(&slots, state);
            for (bi, &(i, start, end)) in round.iter().enumerate() {
                let tl = end - start;
                let live_i = lanes[i].live_i;
                let finished = end == self.live[live_i].prompt.len();
                let lane_cache =
                    self.cache.is_some() && !self.live[live_i].req.params.no_cache;
                if lane_cache {
                    if !finished && stride > 0 && end % stride == 0 {
                        // interior stride snapshot (nested-prefix reuse)
                        let snap = Snapshot {
                            slab: self.pool.snapshot(self.live[live_i].state_slot),
                            logits_row: None,
                        };
                        let key = &self.live[live_i].prompt[..end];
                        self.cache.as_mut().unwrap().insert(key, snap);
                    }
                    if finished {
                        // end-of-prompt snapshot keeps the last logits
                        // row, so an exact resubmission never runs the
                        // model
                        let row =
                            logits[(bi * t_max + tl - 1) * v..(bi * t_max + tl) * v].to_vec();
                        let snap = Snapshot {
                            slab: self.pool.snapshot(self.live[live_i].state_slot),
                            logits_row: Some(row),
                        };
                        self.cache.as_mut().unwrap().insert(&self.live[live_i].prompt, snap);
                    }
                }
                let lr = &mut self.live[live_i];
                if finished {
                    let row = &logits[(bi * t_max + tl - 1) * v..(bi * t_max + tl) * v];
                    let tok = sampler::sample_row(&mut lr.rng, row, v, &lr.req.params);
                    lr.generated.push(tok);
                    lr.phase = Phase::Decoding;
                    lr.prefill_done = Some(std::time::Instant::now());
                    lr.last_token = lr.prefill_done;
                } else {
                    lr.phase = Phase::Prefilling { next: end };
                }
                lanes[i].next = end;
            }
        }
        if let Some(c) = &self.cache {
            self.metrics.record_cache_stats(c.stats());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::SamplingParams;
    use crate::ssm::{MambaModel, MambaTier, QuantConfig, QuantizedMambaModel};

    fn tier() -> MambaTier {
        MambaTier {
            name: "nat".into(),
            d_model: 8,
            n_layer: 2,
            d_state: 4,
            d_conv: 4,
            d_inner: 16,
            dt_rank: 2,
            vocab: 16,
        }
    }

    fn req(id: u64, prompt: Vec<u16>, max_new: usize) -> Request {
        Request {
            id,
            prompt,
            max_new_tokens: max_new,
            params: SamplingParams::default(),
            stop_at_eos: false,
        }
    }

    fn sampled_req(id: u64, prompt: Vec<u16>, max_new: usize) -> Request {
        Request {
            id,
            prompt,
            max_new_tokens: max_new,
            params: SamplingParams { temperature: 0.8, top_k: 8, ..Default::default() },
            stop_at_eos: false,
        }
    }

    #[test]
    fn serves_multi_request_workload() {
        let model = MambaModel::synthetic(tier(), 13);
        let mut eng = NativeEngine::new(Box::new(model), NativeEngineConfig::default());
        for i in 0..10u64 {
            let plen = 2 + (i as usize % 5);
            eng.submit(req(i, (0..plen).map(|j| (j % 16) as u16).collect(), 5 + i as usize % 4));
        }
        let done = eng.run_to_completion().unwrap();
        assert_eq!(done.len(), 10);
        assert_eq!(eng.metrics.requests_done, 10);
        for r in &done {
            let want = 5 + r.id as usize % 4;
            assert_eq!(r.tokens.len(), want, "request {} token count", r.id);
        }
        assert_eq!(eng.n_live(), 0);
        assert_eq!(eng.n_queued(), 0);
    }

    #[test]
    fn empty_prompt_served_as_bos() {
        let model = MambaModel::synthetic(tier(), 13);
        let mut eng = NativeEngine::new(Box::new(model), NativeEngineConfig::default());
        eng.submit(req(1, vec![], 3));
        let done = eng.run_to_completion().unwrap();
        assert_eq!(done[0].tokens.len(), 3);
    }

    #[test]
    fn capacity_backpressure_queues_excess() {
        let model = MambaModel::synthetic(tier(), 13);
        let cfg = NativeEngineConfig { capacity: 2, max_prefills_per_tick: 8, ..Default::default() };
        let mut eng = NativeEngine::new(Box::new(model), cfg);
        for i in 0..5u64 {
            eng.submit(req(i, vec![1, 2, 3], 4));
        }
        eng.step().unwrap();
        assert!(eng.n_live() <= 2);
        assert!(eng.n_queued() >= 3);
        let done = eng.run_to_completion().unwrap();
        assert_eq!(done.len(), 5);
    }

    #[test]
    fn chunked_prefill_advances_across_ticks() {
        // a 20-token prompt with prefill_chunk=4 consumes its prompt
        // over ceil(20/4)=5 ticks, then decodes; the first token shows
        // up only once the whole prompt is in
        let model = MambaModel::synthetic(tier(), 13);
        let cfg = NativeEngineConfig { prefill_chunk: 4, ..Default::default() };
        let mut eng = NativeEngine::new(Box::new(model), cfg);
        eng.submit(req(1, (0..20).map(|j| (j % 16) as u16).collect(), 3));
        for tick in 0..4 {
            eng.step().unwrap();
            assert_eq!(eng.n_prefilling(), 1, "tick {tick}: prompt must still be in flight");
            assert_eq!(eng.tokens_generated(), 0, "tick {tick}: no token before prompt done");
        }
        eng.step().unwrap(); // 5th chunk finishes the prompt → first token
        assert_eq!(eng.n_prefilling(), 0);
        assert_eq!(eng.tokens_generated(), 1);
        let done = eng.run_to_completion().unwrap();
        assert_eq!(done[0].tokens.len(), 3);
    }

    #[test]
    fn token_budget_paces_prefill_behind_decode() {
        // budget 6 with 4 decode lanes leaves 2 prefill tokens/tick:
        // a 10-token prompt admitted mid-decode needs 5 ticks of chunks
        let model = MambaModel::synthetic(tier(), 13);
        let cfg = NativeEngineConfig { max_tokens_per_tick: 6, ..Default::default() };
        let mut eng = NativeEngine::new(Box::new(model), cfg);
        for i in 0..4u64 {
            eng.submit(req(i, vec![1, 2], 32));
        }
        // two admission ticks (max_prefills_per_tick=2) get all 4 decoding
        eng.step().unwrap();
        eng.step().unwrap();
        assert_eq!(eng.n_prefilling(), 0);
        eng.submit(req(9, (0..10).map(|j| (j % 16) as u16).collect(), 2));
        let mut ticks_in_flight = 0;
        while eng.n_live() > 4 || eng.n_queued() > 0 {
            eng.step().unwrap();
            if eng.n_prefilling() > 0 {
                ticks_in_flight += 1;
            }
        }
        assert!(
            ticks_in_flight >= 4,
            "10-token prompt at 2 tokens/tick must stay in flight ≥ 4 ticks \
             (got {ticks_in_flight})"
        );
    }

    fn run_workload(cfg: NativeEngineConfig, quantized: bool) -> Vec<(u64, Vec<u16>)> {
        let t = tier();
        let model = MambaModel::synthetic(t.clone(), 13);
        let mut eng = if quantized {
            let qm = QuantizedMambaModel::from_model(
                &model,
                &(0..64u16).map(|i| i % t.vocab as u16).collect::<Vec<_>>(),
                &QuantConfig::default(),
            );
            NativeEngine::new(Box::new(qm), cfg)
        } else {
            NativeEngine::new(Box::new(model), cfg)
        };
        for i in 0..9u64 {
            let plen = 2 + (i as usize % 4);
            eng.submit(sampled_req(
                i,
                (0..plen).map(|j| ((i as usize + j) % 16) as u16).collect(),
                6 + i as usize % 3,
            ));
        }
        let mut done: Vec<(u64, Vec<u16>)> = eng
            .run_to_completion()
            .unwrap()
            .into_iter()
            .map(|r| (r.id, r.tokens))
            .collect();
        done.sort_by_key(|(id, _)| *id);
        done
    }

    #[test]
    fn same_sampler_seed_same_tokens_across_engines() {
        // satellite acceptance: two engines sharing a sampler seed
        // reproduce each other token-for-token under temperature
        // sampling; the seed is configuration, not a constant
        let cfg = NativeEngineConfig { sampler_seed: 0xDECAF, ..Default::default() };
        let a = run_workload(cfg.clone(), false);
        let b = run_workload(cfg, false);
        assert_eq!(a, b, "same seed must reproduce the token streams");
        // and the seed must actually be wired through: a different seed
        // has to change at least one sampled token (temperature 0.8,
        // top-k 8, ~60 draws — coincidence would mean the config is
        // being ignored, the exact bug this field fixes)
        let c = run_workload(
            NativeEngineConfig { sampler_seed: 0xB16_5EED, ..Default::default() },
            false,
        );
        assert_ne!(a, c, "different sampler seeds produced identical streams — seed ignored?");
    }

    #[test]
    fn threaded_decode_bit_identical_to_sequential() {
        // ISSUE 2 acceptance: threads > 1 produces bit-identical
        // tokens to threads = 1, fp32 and W8A8, incl. sampler state
        for quantized in [false, true] {
            let seq = run_workload(NativeEngineConfig::default(), quantized);
            let par = run_workload(
                NativeEngineConfig { threads: 4, ..Default::default() },
                quantized,
            );
            assert_eq!(
                seq, par,
                "threaded decode diverged from sequential (quantized={quantized})"
            );
        }
    }

    #[test]
    fn forced_kernel_backend_serves_identical_tokens() {
        // ISSUE 3 satellite acceptance: a forced scalar backend, every
        // detected SIMD backend, and auto selection produce
        // bit-identical token streams through the full engine
        // (W8A8 prefill + batched decode + temperature sampling)
        let scalar_cfg = NativeEngineConfig {
            kernel_backend: Some(KernelBackend::Scalar),
            ..Default::default()
        };
        let base = run_workload(scalar_cfg, true);
        for backend in Kernels::available() {
            let cfg = NativeEngineConfig {
                kernel_backend: Some(backend),
                ..Default::default()
            };
            let got = run_workload(cfg, true);
            assert_eq!(base, got, "kernel backend {} changed served tokens", backend.label());
        }
        let auto = run_workload(NativeEngineConfig::default(), true);
        assert_eq!(base, auto, "auto kernel selection diverged from forced scalar");
    }

    #[test]
    fn quantized_pool_shrinks_state_bytes() {
        let t = tier();
        let model = MambaModel::synthetic(t.clone(), 13);
        let qm = QuantizedMambaModel::from_model(&model, &[1, 2, 3, 4], &QuantConfig::default());
        let f_eng = NativeEngine::new(
            Box::new(MambaModel::synthetic(t.clone(), 13)),
            NativeEngineConfig::default(),
        );
        let q_eng = NativeEngine::new(Box::new(qm), NativeEngineConfig::default());
        let cpl = t.n_layer * (t.d_conv - 1) * t.d_inner;
        assert_eq!(
            f_eng.state_bytes_per_request() - q_eng.state_bytes_per_request(),
            3 * cpl,
            "i8 conv window must save 3 bytes per entry"
        );
    }
}
