//! Serving metrics: TTFT / TPOT / TTLT histograms, throughput and
//! queue gauges — the quantities behind paper Table 1 and Fig. 1(a/b)
//! — plus the prefix-cache counters (hits / misses / evicted bytes /
//! prefill tokens saved) behind the warm-TTFT serving story.

use std::time::Instant;

use crate::cache::CacheStats;
use crate::coordinator::request::FinishReason;
use crate::util::rng::Pcg32;
use crate::util::stats::{LogHistogram, Summary};

/// Retained inter-token-gap samples for the exact `itl_summary`. ITL
/// records one sample per generated *token* (unlike the per-request
/// ttft/tpot/ttlt vecs), so an unbounded buffer would grow ~8
/// bytes/token for the life of a serving process; above the cap the
/// buffer switches to deterministic reservoir sampling (Algorithm R,
/// seeded) — exact below the cap (every test/bench workload is), an
/// unbiased sample above it. The `itl_ms` histogram keeps the full
/// stream either way.
pub const ITL_SAMPLE_CAP: usize = 65_536;

pub struct Metrics {
    pub ttft_ms: LogHistogram,
    pub tpot_ms: LogHistogram,
    pub ttlt_ms: LogHistogram,
    pub decode_step_ms: LogHistogram,
    pub prefill_ms: LogHistogram,
    /// per-token inter-token gaps across all finished requests — the
    /// tail of this distribution (p95/max) is what chunked prefill
    /// bounds under bursty long-prompt arrivals
    pub itl_ms: LogHistogram,
    /// raw samples for exact summaries in reports (per-request counts
    /// — bounded by workload size)
    ttft_raw: Vec<f64>,
    tpot_raw: Vec<f64>,
    ttlt_raw: Vec<f64>,
    /// per-token gap samples, reservoir-capped at [`ITL_SAMPLE_CAP`]
    itl_raw: Vec<f64>,
    /// gaps observed so far (reservoir denominator)
    itl_seen: u64,
    itl_rng: Pcg32,
    pub tokens_out: u64,
    pub requests_done: u64,
    /// failure-model outcome counters (ISSUE 7): every submitted
    /// request ends in exactly one of `requests_done` (natural finish)
    /// or these — the chaos suite asserts that conservation
    pub rejected: u64,
    pub deadline_missed: u64,
    pub cancelled: u64,
    pub failed: u64,
    /// prefix-cache snapshot inserts dropped by validation (corrupt
    /// slab) or a panicking cache — degradation the operator should
    /// see, even though tokens are unaffected
    pub snapshot_drops: u64,
    pub padded_lanes: u64,
    pub total_lanes: u64,
    /// last-synced prefix-cache counters (None until an engine with an
    /// active cache calls [`Self::record_cache_stats`])
    pub cache: Option<CacheStats>,
    started: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            ttft_ms: LogHistogram::new(0.01, 60_000.0, 64),
            tpot_ms: LogHistogram::new(0.01, 10_000.0, 64),
            ttlt_ms: LogHistogram::new(0.01, 600_000.0, 64),
            decode_step_ms: LogHistogram::new(0.01, 10_000.0, 64),
            prefill_ms: LogHistogram::new(0.01, 60_000.0, 64),
            itl_ms: LogHistogram::new(0.01, 60_000.0, 64),
            ttft_raw: Vec::new(),
            tpot_raw: Vec::new(),
            ttlt_raw: Vec::new(),
            itl_raw: Vec::new(),
            itl_seen: 0,
            itl_rng: Pcg32::new(0x17A7),
            tokens_out: 0,
            requests_done: 0,
            rejected: 0,
            deadline_missed: 0,
            cancelled: 0,
            failed: 0,
            snapshot_drops: 0,
            padded_lanes: 0,
            total_lanes: 0,
            cache: None,
            started: Instant::now(),
        }
    }

    /// Mirror the engine's prefix-cache counters (overwrite semantics:
    /// the cache owns the authoritative monotonic counts).
    pub fn record_cache_stats(&mut self, stats: CacheStats) {
        self.cache = Some(stats);
    }

    /// Prompt tokens the prefix cache kept out of prefill so far.
    pub fn prefill_tokens_saved(&self) -> u64 {
        self.cache.map_or(0, |c| c.prefill_tokens_saved)
    }

    /// `itl` is the request's per-token inter-token gaps
    /// (`Response::itl_ms`) — recorded individually so the summary can
    /// report true tail percentiles, not just the per-request mean.
    pub fn record_response(
        &mut self,
        ttft: f64,
        tpot: f64,
        ttlt: f64,
        n_tokens: usize,
        itl: &[f64],
    ) {
        if ttft.is_finite() {
            self.ttft_ms.record(ttft);
            self.ttft_raw.push(ttft);
        }
        if tpot.is_finite() {
            self.tpot_ms.record(tpot);
            self.tpot_raw.push(tpot);
        }
        if ttlt.is_finite() {
            self.ttlt_ms.record(ttlt);
            self.ttlt_raw.push(ttlt);
        }
        for &gap in itl {
            if gap.is_finite() {
                self.itl_ms.record(gap);
                self.itl_seen += 1;
                if self.itl_raw.len() < ITL_SAMPLE_CAP {
                    self.itl_raw.push(gap);
                } else {
                    // Algorithm R: keep each seen gap with prob cap/seen
                    let j = (self.itl_rng.next_u64() % self.itl_seen) as usize;
                    if j < ITL_SAMPLE_CAP {
                        self.itl_raw[j] = gap;
                    }
                }
            }
        }
        self.tokens_out += n_tokens as u64;
        self.requests_done += 1;
    }

    /// Count a failure-model outcome. Natural finishes (`Length` /
    /// `Eos`) go through [`Self::record_response`] instead; routing
    /// one through here would double-book the request.
    pub fn record_failure(&mut self, finish: FinishReason) {
        match finish {
            FinishReason::Rejected => self.rejected += 1,
            FinishReason::DeadlineExceeded => self.deadline_missed += 1,
            FinishReason::Cancelled => self.cancelled += 1,
            _ => self.failed += 1,
        }
    }

    /// Total requests that reached *any* terminal outcome.
    pub fn total_outcomes(&self) -> u64 {
        self.requests_done + self.rejected + self.deadline_missed + self.cancelled + self.failed
    }

    /// Fraction of outcomes shed by overload policy (admission
    /// rejection + deadline expiry) — the load-shedding gauge.
    pub fn shed_rate(&self) -> f64 {
        let total = self.total_outcomes();
        if total == 0 {
            0.0
        } else {
            (self.rejected + self.deadline_missed) as f64 / total as f64
        }
    }

    pub fn record_round(&mut self, bucket: usize, live: usize) {
        self.total_lanes += bucket as u64;
        self.padded_lanes += (bucket - live) as u64;
    }

    pub fn throughput_tok_s(&self) -> f64 {
        self.tokens_out as f64 / self.started.elapsed().as_secs_f64().max(1e-9)
    }

    pub fn padding_fraction(&self) -> f64 {
        if self.total_lanes == 0 {
            0.0
        } else {
            self.padded_lanes as f64 / self.total_lanes as f64
        }
    }

    pub fn ttft_summary(&self) -> Summary {
        Summary::of(&self.ttft_raw)
    }
    pub fn tpot_summary(&self) -> Summary {
        Summary::of(&self.tpot_raw)
    }
    pub fn ttlt_summary(&self) -> Summary {
        Summary::of(&self.ttlt_raw)
    }
    /// Summary over the pooled inter-token gaps — exact while at most
    /// [`ITL_SAMPLE_CAP`] gaps have been recorded, a seeded reservoir
    /// sample beyond that (the `itl_ms` histogram always covers the
    /// full stream). p95/max are the chunked-prefill acceptance
    /// quantities.
    pub fn itl_summary(&self) -> Summary {
        Summary::of(&self.itl_raw)
    }

    pub fn report(&self) -> String {
        let t = self.ttft_summary();
        let p = self.tpot_summary();
        let l = self.ttlt_summary();
        let i = self.itl_summary();
        let mut out = format!(
            "requests={} tokens={} throughput={:.1} tok/s padding={:.1}%\n\
             TTFT ms  mean={:.2} p50={:.2} p95={:.2} p99={:.2}\n\
             TPOT ms  mean={:.3} p50={:.3} p99={:.3}\n\
             ITL  ms  mean={:.3} p50={:.3} p95={:.3} max={:.3}\n\
             TTLT ms  mean={:.1} p50={:.1} p99={:.1}",
            self.requests_done,
            self.tokens_out,
            self.throughput_tok_s(),
            100.0 * self.padding_fraction(),
            t.mean, t.p50, t.p95, t.p99,
            p.mean, p.p50, p.p99,
            i.mean, i.p50, i.p95, i.max,
            l.mean, l.p50, l.p99,
        );
        let fail_total = self.rejected + self.deadline_missed + self.cancelled + self.failed;
        if fail_total + self.snapshot_drops > 0 {
            // only when the failure model actually fired — steady-state
            // reports stay unchanged
            out.push_str(&format!(
                "\nfailures rejected={} deadline={} cancelled={} failed={} \
                 snapshot-drops={} shed-rate={:.1}%",
                self.rejected,
                self.deadline_missed,
                self.cancelled,
                self.failed,
                self.snapshot_drops,
                100.0 * self.shed_rate(),
            ));
        }
        if let Some(c) = &self.cache {
            out.push_str(&format!(
                "\nprefix-cache  hits={} misses={} hit-rate={:.1}% entries={} \
                 bytes={}/{} evicted={}B tokens-saved={}",
                c.hits,
                c.misses,
                100.0 * c.hit_rate(),
                c.entries,
                c.bytes_in_use,
                c.capacity_bytes,
                c.evicted_bytes,
                c.prefill_tokens_saved,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_report() {
        let mut m = Metrics::new();
        m.record_response(10.0, 1.0, 50.0, 40, &[1.0, 1.0]);
        m.record_response(20.0, 2.0, 80.0, 30, &[2.0, 9.0]);
        m.record_round(8, 5);
        assert_eq!(m.requests_done, 2);
        assert_eq!(m.tokens_out, 70);
        assert!((m.padding_fraction() - 3.0 / 8.0).abs() < 1e-12);
        let r = m.report();
        assert!(r.contains("requests=2"));
        assert!(r.contains("ITL"), "report must surface inter-token latency: {r}");
        assert!(!r.contains("prefix-cache"), "no cache line until stats are synced");
        assert!((m.ttft_summary().mean - 15.0).abs() < 1e-9);
        let i = m.itl_summary();
        assert_eq!(i.n, 4);
        assert_eq!(i.max, 9.0, "pooled ITL must keep the per-token tail");
        assert_eq!(m.itl_ms.n, 4);
    }

    #[test]
    fn itl_nan_gaps_are_skipped() {
        let mut m = Metrics::new();
        m.record_response(1.0, f64::NAN, 2.0, 1, &[f64::NAN]);
        assert_eq!(m.itl_summary().n, 0);
        assert_eq!(m.requests_done, 1);
    }

    #[test]
    fn itl_raw_buffer_is_bounded() {
        // the retained sample set must stop growing at the cap while
        // the histogram keeps counting the full stream
        let mut m = Metrics::new();
        let gaps = vec![1.0f64; 4096];
        for _ in 0..((2 * ITL_SAMPLE_CAP) / gaps.len()) {
            m.record_response(1.0, 1.0, 1.0, gaps.len(), &gaps);
        }
        assert_eq!(m.itl_summary().n, ITL_SAMPLE_CAP);
        assert_eq!(m.itl_ms.n, 2 * ITL_SAMPLE_CAP as u64);
    }

    #[test]
    fn failure_counters_and_shed_rate() {
        let mut m = Metrics::new();
        // no failures → no failures line, shed rate 0
        m.record_response(10.0, 1.0, 50.0, 4, &[1.0]);
        assert!(!m.report().contains("failures"), "{}", m.report());
        assert_eq!(m.shed_rate(), 0.0);
        m.record_failure(FinishReason::Rejected);
        m.record_failure(FinishReason::Rejected);
        m.record_failure(FinishReason::DeadlineExceeded);
        m.record_failure(FinishReason::Cancelled);
        m.record_failure(FinishReason::Failed);
        m.snapshot_drops += 1;
        assert_eq!(m.total_outcomes(), 6);
        // shed = (2 rejected + 1 deadline) / 6 outcomes
        assert!((m.shed_rate() - 0.5).abs() < 1e-12);
        let r = m.report();
        assert!(r.contains("rejected=2"), "{r}");
        assert!(r.contains("deadline=1"), "{r}");
        assert!(r.contains("cancelled=1"), "{r}");
        assert!(r.contains("failed=1"), "{r}");
        assert!(r.contains("snapshot-drops=1"), "{r}");
        assert!(r.contains("shed-rate=50.0%"), "{r}");
    }

    #[test]
    fn cache_counters_surface_in_report() {
        let mut m = Metrics::new();
        m.record_cache_stats(CacheStats {
            hits: 3,
            misses: 1,
            prefill_tokens_saved: 96,
            evicted_bytes: 128,
            bytes_in_use: 512,
            entries: 2,
            capacity_bytes: 1024,
            ..Default::default()
        });
        assert_eq!(m.prefill_tokens_saved(), 96);
        let r = m.report();
        assert!(r.contains("prefix-cache"), "{r}");
        assert!(r.contains("hits=3"), "{r}");
        assert!(r.contains("hit-rate=75.0%"), "{r}");
        assert!(r.contains("tokens-saved=96"), "{r}");
    }
}
