//! Serving metrics: TTFT / TPOT / TTLT histograms, throughput and
//! queue gauges — the quantities behind paper Table 1 and Fig. 1(a/b)
//! — plus the prefix-cache counters (hits / misses / evicted bytes /
//! prefill tokens saved) behind the warm-TTFT serving story.

use std::time::Instant;

use crate::cache::CacheStats;
use crate::util::stats::{LogHistogram, Summary};

pub struct Metrics {
    pub ttft_ms: LogHistogram,
    pub tpot_ms: LogHistogram,
    pub ttlt_ms: LogHistogram,
    pub decode_step_ms: LogHistogram,
    pub prefill_ms: LogHistogram,
    /// raw samples for exact summaries in reports
    ttft_raw: Vec<f64>,
    tpot_raw: Vec<f64>,
    ttlt_raw: Vec<f64>,
    pub tokens_out: u64,
    pub requests_done: u64,
    pub padded_lanes: u64,
    pub total_lanes: u64,
    /// last-synced prefix-cache counters (None until an engine with an
    /// active cache calls [`Self::record_cache_stats`])
    pub cache: Option<CacheStats>,
    started: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            ttft_ms: LogHistogram::new(0.01, 60_000.0, 64),
            tpot_ms: LogHistogram::new(0.01, 10_000.0, 64),
            ttlt_ms: LogHistogram::new(0.01, 600_000.0, 64),
            decode_step_ms: LogHistogram::new(0.01, 10_000.0, 64),
            prefill_ms: LogHistogram::new(0.01, 60_000.0, 64),
            ttft_raw: Vec::new(),
            tpot_raw: Vec::new(),
            ttlt_raw: Vec::new(),
            tokens_out: 0,
            requests_done: 0,
            padded_lanes: 0,
            total_lanes: 0,
            cache: None,
            started: Instant::now(),
        }
    }

    /// Mirror the engine's prefix-cache counters (overwrite semantics:
    /// the cache owns the authoritative monotonic counts).
    pub fn record_cache_stats(&mut self, stats: CacheStats) {
        self.cache = Some(stats);
    }

    /// Prompt tokens the prefix cache kept out of prefill so far.
    pub fn prefill_tokens_saved(&self) -> u64 {
        self.cache.map_or(0, |c| c.prefill_tokens_saved)
    }

    pub fn record_response(&mut self, ttft: f64, tpot: f64, ttlt: f64, n_tokens: usize) {
        if ttft.is_finite() {
            self.ttft_ms.record(ttft);
            self.ttft_raw.push(ttft);
        }
        if tpot.is_finite() {
            self.tpot_ms.record(tpot);
            self.tpot_raw.push(tpot);
        }
        if ttlt.is_finite() {
            self.ttlt_ms.record(ttlt);
            self.ttlt_raw.push(ttlt);
        }
        self.tokens_out += n_tokens as u64;
        self.requests_done += 1;
    }

    pub fn record_round(&mut self, bucket: usize, live: usize) {
        self.total_lanes += bucket as u64;
        self.padded_lanes += (bucket - live) as u64;
    }

    pub fn throughput_tok_s(&self) -> f64 {
        self.tokens_out as f64 / self.started.elapsed().as_secs_f64().max(1e-9)
    }

    pub fn padding_fraction(&self) -> f64 {
        if self.total_lanes == 0 {
            0.0
        } else {
            self.padded_lanes as f64 / self.total_lanes as f64
        }
    }

    pub fn ttft_summary(&self) -> Summary {
        Summary::of(&self.ttft_raw)
    }
    pub fn tpot_summary(&self) -> Summary {
        Summary::of(&self.tpot_raw)
    }
    pub fn ttlt_summary(&self) -> Summary {
        Summary::of(&self.ttlt_raw)
    }

    pub fn report(&self) -> String {
        let t = self.ttft_summary();
        let p = self.tpot_summary();
        let l = self.ttlt_summary();
        let mut out = format!(
            "requests={} tokens={} throughput={:.1} tok/s padding={:.1}%\n\
             TTFT ms  mean={:.2} p50={:.2} p99={:.2}\n\
             TPOT ms  mean={:.3} p50={:.3} p99={:.3}\n\
             TTLT ms  mean={:.1} p50={:.1} p99={:.1}",
            self.requests_done,
            self.tokens_out,
            self.throughput_tok_s(),
            100.0 * self.padding_fraction(),
            t.mean, t.p50, t.p99,
            p.mean, p.p50, p.p99,
            l.mean, l.p50, l.p99,
        );
        if let Some(c) = &self.cache {
            out.push_str(&format!(
                "\nprefix-cache  hits={} misses={} hit-rate={:.1}% entries={} \
                 bytes={}/{} evicted={}B tokens-saved={}",
                c.hits,
                c.misses,
                100.0 * c.hit_rate(),
                c.entries,
                c.bytes_in_use,
                c.capacity_bytes,
                c.evicted_bytes,
                c.prefill_tokens_saved,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_report() {
        let mut m = Metrics::new();
        m.record_response(10.0, 1.0, 50.0, 40);
        m.record_response(20.0, 2.0, 80.0, 30);
        m.record_round(8, 5);
        assert_eq!(m.requests_done, 2);
        assert_eq!(m.tokens_out, 70);
        assert!((m.padding_fraction() - 3.0 / 8.0).abs() < 1e-12);
        let r = m.report();
        assert!(r.contains("requests=2"));
        assert!(!r.contains("prefix-cache"), "no cache line until stats are synced");
        assert!((m.ttft_summary().mean - 15.0).abs() < 1e-9);
    }

    #[test]
    fn cache_counters_surface_in_report() {
        let mut m = Metrics::new();
        m.record_cache_stats(CacheStats {
            hits: 3,
            misses: 1,
            prefill_tokens_saved: 96,
            evicted_bytes: 128,
            bytes_in_use: 512,
            entries: 2,
            capacity_bytes: 1024,
            ..Default::default()
        });
        assert_eq!(m.prefill_tokens_saved(), 96);
        let r = m.report();
        assert!(r.contains("prefix-cache"), "{r}");
        assert!(r.contains("hits=3"), "{r}");
        assert!(r.contains("hit-rate=75.0%"), "{r}");
        assert!(r.contains("tokens-saved=96"), "{r}");
    }
}
