//! Serving metrics: TTFT / TPOT / ITL / TTLT, per-tick duration and
//! queue-depth distributions, throughput and outcome counters — the
//! quantities behind paper Table 1 and Fig. 1(a/b) — plus the
//! prefix-cache counters (hits / misses / evicted bytes / prefill
//! tokens saved) behind the warm-TTFT serving story.
//!
//! Since ISSUE 9 every distribution is a mergeable constant-memory
//! log₂-bucket histogram ([`LogHistogram`]): no retained sample
//! vectors, no reservoir cap — memory is fixed at ~600 bytes per
//! distribution no matter how many tokens flow, mean/max/count stay
//! exact, interior percentiles are bucket-quantized (≤ one power of
//! two), and two engines' metrics merge into exactly what one engine
//! would have recorded. The whole state also crosses the engine
//! mailbox as a typed [`MetricsSnapshot`] (not a formatted string), so
//! the `/metrics` exporter and tests consume numbers.

use crate::cache::CacheStats;
use crate::coordinator::faults::WallAnchor;
use crate::coordinator::request::FinishReason;
use crate::obs::hist::LogHistogram;
use crate::util::stats::Summary;

pub struct Metrics {
    pub ttft_ms: LogHistogram,
    pub tpot_ms: LogHistogram,
    pub ttlt_ms: LogHistogram,
    pub decode_step_ms: LogHistogram,
    pub prefill_ms: LogHistogram,
    /// per-token inter-token gaps across all finished requests — the
    /// tail of this distribution (p95/p99/max) is what chunked prefill
    /// bounds under bursty long-prompt arrivals, and the p99 is the
    /// multi-tenant SLO gauge the exporter publishes
    pub itl_ms: LogHistogram,
    /// wall duration of each engine tick (engine clock)
    pub tick_ms: LogHistogram,
    /// submit-queue depth sampled once per tick
    pub queue_depth: LogHistogram,
    pub tokens_out: u64,
    pub requests_done: u64,
    /// failure-model outcome counters (ISSUE 7): every submitted
    /// request ends in exactly one of `requests_done` (natural finish)
    /// or these — the chaos suite asserts that conservation
    pub rejected: u64,
    pub deadline_missed: u64,
    pub cancelled: u64,
    pub failed: u64,
    /// prefix-cache snapshot inserts dropped by validation (corrupt
    /// slab) or a panicking cache — degradation the operator should
    /// see, even though tokens are unaffected
    pub snapshot_drops: u64,
    pub padded_lanes: u64,
    pub total_lanes: u64,
    /// per-round speculative acceptance length (accepted draft tokens
    /// per verify round, ISSUE 10) — the distribution behind the
    /// adaptive-K policy and the `accept_len_mean` bench key
    pub spec_accept_len: LogHistogram,
    /// completed draft→verify rounds
    pub spec_rounds: u64,
    /// draft tokens proposed across all rounds
    pub spec_drafted_tokens: u64,
    /// draft tokens accepted by target verification (the
    /// `quamba_spec_accepted_tokens` exporter series)
    pub spec_accepted_tokens: u64,
    /// last-synced prefix-cache counters (None until an engine with an
    /// active cache calls [`Self::record_cache_stats`])
    pub cache: Option<CacheStats>,
    anchor: WallAnchor,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            ttft_ms: LogHistogram::new(),
            tpot_ms: LogHistogram::new(),
            ttlt_ms: LogHistogram::new(),
            decode_step_ms: LogHistogram::new(),
            prefill_ms: LogHistogram::new(),
            itl_ms: LogHistogram::new(),
            tick_ms: LogHistogram::new(),
            queue_depth: LogHistogram::new(),
            tokens_out: 0,
            requests_done: 0,
            rejected: 0,
            deadline_missed: 0,
            cancelled: 0,
            failed: 0,
            snapshot_drops: 0,
            padded_lanes: 0,
            total_lanes: 0,
            spec_accept_len: LogHistogram::new(),
            spec_rounds: 0,
            spec_drafted_tokens: 0,
            spec_accepted_tokens: 0,
            cache: None,
            anchor: WallAnchor::new(),
        }
    }

    /// Mirror the engine's prefix-cache counters (overwrite semantics:
    /// the cache owns the authoritative monotonic counts).
    pub fn record_cache_stats(&mut self, stats: CacheStats) {
        self.cache = Some(stats);
    }

    /// Prompt tokens the prefix cache kept out of prefill so far.
    pub fn prefill_tokens_saved(&self) -> u64 {
        self.cache.map_or(0, |c| c.prefill_tokens_saved)
    }

    /// `itl` is the request's per-token inter-token gaps
    /// (`Response::itl_ms`) — recorded individually so the pooled
    /// distribution keeps true tail percentiles, not just the
    /// per-request mean. Non-finite samples (no-gap sentinels) are
    /// dropped by the histogram.
    pub fn record_response(
        &mut self,
        ttft: f64,
        tpot: f64,
        ttlt: f64,
        n_tokens: usize,
        itl: &[f64],
    ) {
        self.ttft_ms.record(ttft);
        self.tpot_ms.record(tpot);
        self.ttlt_ms.record(ttlt);
        for &gap in itl {
            self.itl_ms.record(gap);
        }
        self.tokens_out += n_tokens as u64;
        self.requests_done += 1;
    }

    /// Count a failure-model outcome. Natural finishes (`Length` /
    /// `Eos`) go through [`Self::record_response`] instead; routing
    /// one through here would double-book the request.
    pub fn record_failure(&mut self, finish: FinishReason) {
        match finish {
            FinishReason::Rejected => self.rejected += 1,
            FinishReason::DeadlineExceeded => self.deadline_missed += 1,
            FinishReason::Cancelled => self.cancelled += 1,
            _ => self.failed += 1,
        }
    }

    /// Total requests that reached *any* terminal outcome.
    pub fn total_outcomes(&self) -> u64 {
        self.requests_done + self.rejected + self.deadline_missed + self.cancelled + self.failed
    }

    /// Fraction of outcomes shed by overload policy (admission
    /// rejection + deadline expiry) — the load-shedding gauge.
    pub fn shed_rate(&self) -> f64 {
        let total = self.total_outcomes();
        if total == 0 {
            0.0
        } else {
            (self.rejected + self.deadline_missed) as f64 / total as f64
        }
    }

    pub fn record_round(&mut self, bucket: usize, live: usize) {
        self.total_lanes += bucket as u64;
        self.padded_lanes += (bucket - live) as u64;
    }

    /// One speculative draft→verify round for one lane: `drafted`
    /// tokens proposed, `accepted` of them confirmed by the target
    /// (`accepted <= drafted`). The resampled/bonus token is *not*
    /// counted here — it exists in plain decode too.
    pub fn record_spec_round(&mut self, drafted: usize, accepted: usize) {
        debug_assert!(accepted <= drafted);
        self.spec_rounds += 1;
        self.spec_drafted_tokens += drafted as u64;
        self.spec_accepted_tokens += accepted as u64;
        self.spec_accept_len.record(accepted as f64);
    }

    /// Mean accepted draft tokens per verify round (0 when speculation
    /// never ran) — the `accept_len_mean` bench / report gauge.
    pub fn spec_accept_len_mean(&self) -> f64 {
        if self.spec_rounds == 0 {
            0.0
        } else {
            self.spec_accepted_tokens as f64 / self.spec_rounds as f64
        }
    }

    /// One engine tick: its duration and the submit-queue depth at its
    /// end, both on the engine clock.
    pub fn record_tick(&mut self, tick_ms: f64, queue_depth: usize) {
        self.tick_ms.record(tick_ms);
        self.queue_depth.record(queue_depth as f64);
    }

    /// Wall-clock throughput since construction (real time, even under
    /// `Clock::Manual` — this is the operator-facing report gauge; the
    /// deterministic equivalent lives in [`Self::snapshot`]).
    pub fn throughput_tok_s(&self) -> f64 {
        self.tokens_out as f64 / (self.anchor.elapsed_ms() / 1e3).max(1e-9)
    }

    pub fn padding_fraction(&self) -> f64 {
        if self.total_lanes == 0 {
            0.0
        } else {
            self.padded_lanes as f64 / self.total_lanes as f64
        }
    }

    pub fn ttft_summary(&self) -> Summary {
        self.ttft_ms.summary()
    }
    pub fn tpot_summary(&self) -> Summary {
        self.tpot_ms.summary()
    }
    pub fn ttlt_summary(&self) -> Summary {
        self.ttlt_ms.summary()
    }
    /// Summary over the pooled inter-token gaps (full stream, constant
    /// memory — mean/max/count exact, percentiles bucket-quantized).
    /// p95/p99/max are the chunked-prefill and SLO tail quantities.
    pub fn itl_summary(&self) -> Summary {
        self.itl_ms.summary()
    }

    /// The typed state that crosses the engine mailbox: every counter
    /// and histogram by value. `now_ms` is the engine-clock timestamp
    /// (deterministic under `Clock::Manual`, so two identical seeded
    /// runs produce *equal* snapshots), used for the deterministic
    /// `tok_per_s` gauge.
    pub fn snapshot(&self, now_ms: f64) -> MetricsSnapshot {
        MetricsSnapshot {
            requests_done: self.requests_done,
            rejected: self.rejected,
            deadline_missed: self.deadline_missed,
            cancelled: self.cancelled,
            failed: self.failed,
            tokens_out: self.tokens_out,
            snapshot_drops: self.snapshot_drops,
            padded_lanes: self.padded_lanes,
            total_lanes: self.total_lanes,
            spec_accept_len: self.spec_accept_len.clone(),
            spec_rounds: self.spec_rounds,
            spec_drafted_tokens: self.spec_drafted_tokens,
            spec_accepted_tokens: self.spec_accepted_tokens,
            elapsed_ms: now_ms,
            tok_per_s: self.tokens_out as f64 / (now_ms / 1e3).max(1e-9),
            shed_rate: self.shed_rate(),
            ttft_ms: self.ttft_ms.clone(),
            tpot_ms: self.tpot_ms.clone(),
            ttlt_ms: self.ttlt_ms.clone(),
            itl_ms: self.itl_ms.clone(),
            tick_ms: self.tick_ms.clone(),
            queue_depth: self.queue_depth.clone(),
            cache: self.cache,
        }
    }

    pub fn report(&self) -> String {
        let t = self.ttft_summary();
        let p = self.tpot_summary();
        let l = self.ttlt_summary();
        let i = self.itl_summary();
        let mut out = format!(
            "requests={} tokens={} throughput={:.1} tok/s padding={:.1}%\n\
             TTFT ms  mean={:.2} p50={:.2} p95={:.2} p99={:.2}\n\
             TPOT ms  mean={:.3} p50={:.3} p99={:.3}\n\
             ITL  ms  mean={:.3} p50={:.3} p95={:.3} p99={:.3} max={:.3}\n\
             TTLT ms  mean={:.1} p50={:.1} p99={:.1}",
            self.requests_done,
            self.tokens_out,
            self.throughput_tok_s(),
            100.0 * self.padding_fraction(),
            t.mean, t.p50, t.p95, t.p99,
            p.mean, p.p50, p.p99,
            i.mean, i.p50, i.p95, i.p99, i.max,
            l.mean, l.p50, l.p99,
        );
        let fail_total = self.rejected + self.deadline_missed + self.cancelled + self.failed;
        if fail_total + self.snapshot_drops > 0 {
            // only when the failure model actually fired — steady-state
            // reports stay unchanged
            out.push_str(&format!(
                "\nfailures rejected={} deadline={} cancelled={} failed={} \
                 snapshot-drops={} shed-rate={:.1}%",
                self.rejected,
                self.deadline_missed,
                self.cancelled,
                self.failed,
                self.snapshot_drops,
                100.0 * self.shed_rate(),
            ));
        }
        if self.spec_rounds > 0 {
            // only when speculation actually ran — plain-decode
            // reports stay unchanged
            out.push_str(&format!(
                "\nspec-decode rounds={} drafted={} accepted={} accept-rate={:.1}% \
                 accept-len mean={:.2} p50={:.0} max={:.0}",
                self.spec_rounds,
                self.spec_drafted_tokens,
                self.spec_accepted_tokens,
                100.0 * self.spec_accepted_tokens as f64
                    / (self.spec_drafted_tokens as f64).max(1.0),
                self.spec_accept_len_mean(),
                self.spec_accept_len.summary().p50,
                self.spec_accept_len.summary().max,
            ));
        }
        if let Some(c) = &self.cache {
            out.push_str(&format!(
                "\nprefix-cache  hits={} misses={} hit-rate={:.1}% entries={} \
                 bytes={}/{} evicted={}B tokens-saved={}",
                c.hits,
                c.misses,
                100.0 * c.hit_rate(),
                c.entries,
                c.bytes_in_use,
                c.capacity_bytes,
                c.evicted_bytes,
                c.prefill_tokens_saved,
            ));
        }
        out
    }
}

/// Every metric by value: the typed struct that crosses the engine
/// mailbox (`Msg::MetricsSnapshot`) so exporters and tests consume
/// numbers, not a formatted report string. `PartialEq` + `Clone` so
/// determinism tests can assert two seeded manual-clock runs produce
/// *equal* snapshots.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub requests_done: u64,
    pub rejected: u64,
    pub deadline_missed: u64,
    pub cancelled: u64,
    pub failed: u64,
    pub tokens_out: u64,
    pub snapshot_drops: u64,
    pub padded_lanes: u64,
    pub total_lanes: u64,
    /// accepted-draft-tokens-per-round distribution (ISSUE 10)
    pub spec_accept_len: LogHistogram,
    pub spec_rounds: u64,
    pub spec_drafted_tokens: u64,
    pub spec_accepted_tokens: u64,
    /// engine-clock timestamp the snapshot was taken at
    pub elapsed_ms: f64,
    /// tokens / engine-clock seconds (deterministic under the manual
    /// clock, wall throughput under `Clock::Wall`)
    pub tok_per_s: f64,
    pub shed_rate: f64,
    pub ttft_ms: LogHistogram,
    pub tpot_ms: LogHistogram,
    pub ttlt_ms: LogHistogram,
    pub itl_ms: LogHistogram,
    pub tick_ms: LogHistogram,
    pub queue_depth: LogHistogram,
    pub cache: Option<CacheStats>,
}

impl MetricsSnapshot {
    /// Requests that reached any terminal outcome.
    pub fn total_outcomes(&self) -> u64 {
        self.requests_done + self.rejected + self.deadline_missed + self.cancelled + self.failed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_report() {
        let mut m = Metrics::new();
        m.record_response(10.0, 1.0, 50.0, 40, &[1.0, 1.0]);
        m.record_response(20.0, 2.0, 80.0, 30, &[2.0, 9.0]);
        m.record_round(8, 5);
        assert_eq!(m.requests_done, 2);
        assert_eq!(m.tokens_out, 70);
        assert!((m.padding_fraction() - 3.0 / 8.0).abs() < 1e-12);
        let r = m.report();
        assert!(r.contains("requests=2"));
        assert!(r.contains("ITL"), "report must surface inter-token latency: {r}");
        assert!(r.contains("p99="), "ITL p99 is the SLO gauge and must be printed: {r}");
        assert!(!r.contains("prefix-cache"), "no cache line until stats are synced");
        assert!((m.ttft_summary().mean - 15.0).abs() < 1e-9, "histogram means stay exact");
        let i = m.itl_summary();
        assert_eq!(i.n, 4);
        assert_eq!(i.max, 9.0, "pooled ITL must keep the per-token tail exactly");
        assert_eq!(m.itl_ms.count, 4);
    }

    #[test]
    fn itl_nan_gaps_are_skipped() {
        let mut m = Metrics::new();
        m.record_response(1.0, f64::NAN, 2.0, 1, &[f64::NAN]);
        assert_eq!(m.itl_summary().n, 0);
        assert_eq!(m.tpot_ms.count, 0, "NaN TPOT must not be recorded");
        assert_eq!(m.requests_done, 1);
    }

    #[test]
    fn itl_memory_is_constant_and_stream_is_uncapped() {
        // the old reservoir capped the retained ITL sample set; the
        // histogram records the FULL stream in constant memory — count,
        // sum and max stay exact at any volume
        let mut m = Metrics::new();
        let gaps = vec![1.0f64; 4096];
        let rounds = 64usize; // 256k gaps — 4x the old reservoir cap
        for _ in 0..rounds {
            m.record_response(1.0, 1.0, 1.0, gaps.len(), &gaps);
        }
        let n = (rounds * gaps.len()) as u64;
        assert_eq!(m.itl_ms.count, n);
        assert_eq!(m.itl_summary().n, n as usize, "no sample cap anymore");
        assert_eq!(m.itl_ms.sum, n as f64);
        assert_eq!(
            std::mem::size_of_val(&m.itl_ms),
            std::mem::size_of::<LogHistogram>(),
            "the histogram is a flat fixed-size value — nothing grows with the stream"
        );
    }

    #[test]
    fn snapshot_is_typed_and_deterministic() {
        let mk = || {
            let mut m = Metrics::new();
            m.record_response(10.0, 1.0, 50.0, 40, &[1.0, 1.5]);
            m.record_failure(FinishReason::Rejected);
            m.record_tick(2.0, 3);
            m.snapshot(100.0)
        };
        let a = mk();
        let b = mk();
        assert_eq!(a, b, "identical recording → equal snapshots (wall time never leaks in)");
        assert_eq!(a.requests_done, 1);
        assert_eq!(a.rejected, 1);
        assert_eq!(a.tokens_out, 40);
        assert_eq!(a.total_outcomes(), 2);
        assert!((a.tok_per_s - 400.0).abs() < 1e-9, "40 tokens / 0.1 s on the engine clock");
        assert_eq!(a.tick_ms.count, 1);
        assert_eq!(a.queue_depth.count, 1);
        assert_eq!(a.itl_ms.count, 2);
    }

    #[test]
    fn merged_snapshots_equal_single_recorder() {
        // the replica-routing story: two engines' histograms combine
        // into exactly one engine's view
        let mut a = Metrics::new();
        let mut b = Metrics::new();
        let mut whole = Metrics::new();
        for i in 0..40 {
            let ttft = 1.0 + i as f64;
            whole.record_response(ttft, 0.5, ttft * 2.0, 4, &[0.5, 0.7]);
            if i % 2 == 0 { &mut a } else { &mut b }.record_response(
                ttft,
                0.5,
                ttft * 2.0,
                4,
                &[0.5, 0.7],
            );
        }
        let mut merged = a.ttft_ms.clone();
        merged.merge(&b.ttft_ms);
        assert_eq!(merged, whole.ttft_ms);
    }

    #[test]
    fn failure_counters_and_shed_rate() {
        let mut m = Metrics::new();
        // no failures → no failures line, shed rate 0
        m.record_response(10.0, 1.0, 50.0, 4, &[1.0]);
        assert!(!m.report().contains("failures"), "{}", m.report());
        assert_eq!(m.shed_rate(), 0.0);
        m.record_failure(FinishReason::Rejected);
        m.record_failure(FinishReason::Rejected);
        m.record_failure(FinishReason::DeadlineExceeded);
        m.record_failure(FinishReason::Cancelled);
        m.record_failure(FinishReason::Failed);
        m.snapshot_drops += 1;
        assert_eq!(m.total_outcomes(), 6);
        // shed = (2 rejected + 1 deadline) / 6 outcomes
        assert!((m.shed_rate() - 0.5).abs() < 1e-12);
        let r = m.report();
        assert!(r.contains("rejected=2"), "{r}");
        assert!(r.contains("deadline=1"), "{r}");
        assert!(r.contains("cancelled=1"), "{r}");
        assert!(r.contains("failed=1"), "{r}");
        assert!(r.contains("snapshot-drops=1"), "{r}");
        assert!(r.contains("shed-rate=50.0%"), "{r}");
    }

    #[test]
    fn spec_rounds_surface_in_report_and_snapshot() {
        let mut m = Metrics::new();
        // no speculation → no spec line (plain-decode reports unchanged)
        assert!(!m.report().contains("spec-decode"), "{}", m.report());
        assert_eq!(m.spec_accept_len_mean(), 0.0);
        m.record_spec_round(4, 4);
        m.record_spec_round(4, 1);
        m.record_spec_round(2, 0);
        assert_eq!(m.spec_rounds, 3);
        assert_eq!(m.spec_drafted_tokens, 10);
        assert_eq!(m.spec_accepted_tokens, 5);
        assert!((m.spec_accept_len_mean() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(m.spec_accept_len.count, 3);
        let r = m.report();
        assert!(r.contains("spec-decode rounds=3"), "{r}");
        assert!(r.contains("drafted=10"), "{r}");
        assert!(r.contains("accepted=5"), "{r}");
        assert!(r.contains("accept-rate=50.0%"), "{r}");
        let s = m.snapshot(100.0);
        assert_eq!(s.spec_rounds, 3);
        assert_eq!(s.spec_accepted_tokens, 5);
        assert_eq!(s.spec_accept_len.count, 3);
    }

    #[test]
    fn cache_counters_surface_in_report() {
        let mut m = Metrics::new();
        m.record_cache_stats(CacheStats {
            hits: 3,
            misses: 1,
            prefill_tokens_saved: 96,
            evicted_bytes: 128,
            bytes_in_use: 512,
            entries: 2,
            capacity_bytes: 1024,
            ..Default::default()
        });
        assert_eq!(m.prefill_tokens_saved(), 96);
        let r = m.report();
        assert!(r.contains("prefix-cache"), "{r}");
        assert!(r.contains("hits=3"), "{r}");
        assert!(r.contains("hit-rate=75.0%"), "{r}");
        assert!(r.contains("tokens-saved=96"), "{r}");
    }
}
