//! Deterministic fault injection and the injectable engine clock.
//!
//! The failure model (ISSUE 7) is only trustworthy if its error paths
//! are *exercised*, and they are only testable if the faults that
//! trigger them are **deterministic**: a [`FaultPlan`] decides
//! hit-or-miss as a pure hash of `(plan seed, site, request id, step)`
//! — no shared RNG stream whose consumption order would couple fault
//! placement to scheduler interleaving. That statelessness is
//! load-bearing: when the engine retries a panicked round without the
//! victim, every surviving lane re-rolls the *same* keys and gets the
//! same answers, so a seeded chaos run is replayable tick-for-tick.
//!
//! The default plan ([`FaultPlan::none`]) injects nothing and is
//! zero-cost on the hot path: every probability is 0.0 and the
//! targeted list is empty, so each hook is a couple of float compares.
//!
//! [`Clock`] is the companion knob: deadlines are checked at tick
//! boundaries against `Clock::Wall` (real time) or `Clock::Manual`
//! (tick count × a fixed ms-per-tick), the latter making deadline
//! expiry — and therefore whole chaos schedules — bit-reproducible.
//!
//! **Clock discipline (ISSUE 9):** this module is the ONLY place in
//! `coordinator/` and `obs/` allowed to read raw time. Everything else
//! — engines, metrics, request stamps, the flight recorder — takes
//! `f64` milliseconds that originated either from `Clock::Manual`
//! arithmetic or from a [`WallAnchor`] held by an engine. The
//! `clock-discipline` rule in `quamba-audit` enforces this: a raw
//! `Instant::now()` / `SystemTime::now()` anywhere else on the serving
//! path is a finding, because it would make traces and metrics
//! snapshots non-reproducible under the manual clock.

use std::any::Any;
use std::time::Instant;

/// Engine time source for deadline checks. `Wall` anchors at engine
/// construction; `Manual` is deterministic — `now = tick ×
/// ms_per_tick + injected latency` — so deadline schedules in the
/// chaos suite replay identically on every run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Clock {
    Wall,
    Manual { ms_per_tick: f64 },
}

impl Default for Clock {
    fn default() -> Self {
        Clock::Wall
    }
}

/// The sanctioned wall-clock reader for the serving path: a fixed
/// epoch captured at construction, read as `f64` milliseconds since.
///
/// Engines hold one `WallAnchor` and derive every `Clock::Wall`
/// timestamp from it; under `Clock::Manual` they never consult it, so
/// manual-clock runs stay bit-reproducible. Confining the raw
/// `Instant` reads to this type (checked by the auditor's
/// `clock-discipline` rule) keeps time injectable everywhere else.
#[derive(Debug, Clone, Copy)]
pub struct WallAnchor {
    epoch: Instant,
}

impl WallAnchor {
    #[allow(clippy::new_without_default)] // an anchor is an explicit act, not a default
    pub fn new() -> WallAnchor {
        WallAnchor { epoch: Instant::now() }
    }

    /// Milliseconds elapsed since the anchor was created.
    #[inline]
    pub fn elapsed_ms(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64() * 1e3
    }
}

/// Where in the tick anatomy a fault fires (see
/// `docs/ARCHITECTURE.md` §7 for the mapping onto the scheduler).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// panic inside a decode round, keyed by (request, tokens sampled)
    Decode,
    /// panic inside a prefill sub-round, keyed by (request, prompt pos)
    Prefill,
    /// admission-time state-pool allocation failure for a request
    Alloc,
    /// corrupt the snapshot slab before a prefix-cache insert
    Snapshot,
    /// panic inside a speculative draft round (catch-up prefill or
    /// proposal steps), keyed by (request, tokens sampled)
    Draft,
    /// panic inside a speculative verify batch, keyed by (request,
    /// tokens sampled) — the chaos suite asserts the pre-draft
    /// snapshot survives and the lane's token stream stays bit-exact
    Verify,
}

/// One explicit injection: fire at exactly this (site, request, step)
/// key, independent of the seeded rates. The chaos suite uses these
/// for the "fails exactly one request" demonstrations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TargetedFault {
    pub site: FaultSite,
    pub req_id: u64,
    pub step: u64,
}

/// Panic payload for injected faults: [`FaultPlan::check`] throws it
/// via `panic_any` inside the engine's `catch_unwind` regions, and the
/// catcher downcasts it to attribute the failure to exactly one
/// request. A payload that is *not* an `InjectedFault` is a genuine
/// model bug, and the catcher conservatively fails the whole round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InjectedFault {
    pub req_id: u64,
    pub site: FaultSite,
}

/// A seeded, stateless schedule of injected failures.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    /// per-(request, step) probability of a decode-round panic
    pub decode_panic: f64,
    /// per-(request, chunk-start) probability of a prefill panic
    pub prefill_panic: f64,
    /// per-request probability that admission's slot allocation fails
    pub alloc_fail: f64,
    /// per-insert probability of corrupting the snapshot slab (the
    /// engine's validation must catch it and drop the insert)
    pub snapshot_corrupt: f64,
    /// per-(request, step) probability of a draft-round panic
    /// (speculative decoding's draft catch-up / proposal phase)
    pub draft_panic: f64,
    /// per-(request, step) probability of a verify-batch panic
    /// (speculative decoding's target verification phase)
    pub verify_panic: f64,
    /// per-tick probability of `tick_latency_ms` of injected latency
    pub tick_latency_p: f64,
    /// injected latency magnitude (advances `Clock::Manual` time;
    /// sleeps under `Clock::Wall`)
    pub tick_latency_ms: f64,
    /// explicit one-shot injections, checked before the seeded rates
    pub targeted: Vec<TargetedFault>,
}

/// Splitmix-style stateless mixer: the decision for one key never
/// depends on which other keys were rolled, or in what order.
fn mix(seed: u64, kind: u64, a: u64, b: u64) -> u64 {
    let mut z = seed
        .wrapping_add(kind.rotate_left(16).wrapping_mul(0xD6E8_FEB8_6659_FD93))
        .wrapping_add(a.wrapping_mul(0x9E37_79B9_7F4A_7C15))
        .wrapping_add(b.rotate_left(32).wrapping_mul(0xA076_1D64_78BD_642F));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Uniform [0,1) from the top 53 bits of a mixed hash.
fn unit(h: u64) -> f64 {
    (h >> 11) as f64 / (1u64 << 53) as f64
}

impl FaultPlan {
    /// No faults — the production default. All hooks short-circuit.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// A uniform chaos schedule: every site fires with probability
    /// `rate`, latency spikes of 3 ms at the same rate.
    pub fn seeded(seed: u64, rate: f64) -> FaultPlan {
        FaultPlan {
            seed,
            decode_panic: rate,
            prefill_panic: rate,
            alloc_fail: rate,
            snapshot_corrupt: rate,
            draft_panic: rate,
            verify_panic: rate,
            tick_latency_p: rate,
            tick_latency_ms: 3.0,
            targeted: Vec::new(),
        }
    }

    pub fn enabled(&self) -> bool {
        self.decode_panic > 0.0
            || self.prefill_panic > 0.0
            || self.alloc_fail > 0.0
            || self.snapshot_corrupt > 0.0
            || self.draft_panic > 0.0
            || self.verify_panic > 0.0
            || self.tick_latency_p > 0.0
            || !self.targeted.is_empty()
    }

    fn site_kind(site: FaultSite) -> u64 {
        match site {
            FaultSite::Decode => 1,
            FaultSite::Prefill => 2,
            FaultSite::Alloc => 3,
            FaultSite::Snapshot => 4,
            FaultSite::Draft => 5,
            FaultSite::Verify => 6,
        }
    }

    /// Pure decision: does the plan inject a fault at this key?
    pub fn should_fail(&self, site: FaultSite, req_id: u64, step: u64) -> bool {
        if self.targeted.iter().any(|t| t.site == site && t.req_id == req_id && t.step == step) {
            return true;
        }
        let p = match site {
            FaultSite::Decode => self.decode_panic,
            FaultSite::Prefill => self.prefill_panic,
            FaultSite::Alloc => self.alloc_fail,
            FaultSite::Snapshot => self.snapshot_corrupt,
            FaultSite::Draft => self.draft_panic,
            FaultSite::Verify => self.verify_panic,
        };
        p > 0.0 && unit(mix(self.seed, Self::site_kind(site), req_id, step)) < p
    }

    /// Panic (with an attributable [`InjectedFault`] payload) when the
    /// plan injects at this key. Called inside the engine's
    /// `catch_unwind` regions only.
    pub fn check(&self, site: FaultSite, req_id: u64, step: u64) {
        if self.should_fail(site, req_id, step) {
            std::panic::panic_any(InjectedFault { req_id, site });
        }
    }

    /// Injected latency for this tick (0.0 = none this tick).
    pub fn injected_latency_ms(&self, tick: u64) -> f64 {
        if self.tick_latency_p > 0.0 && unit(mix(self.seed, 0xFA, tick, 0)) < self.tick_latency_p
        {
            self.tick_latency_ms
        } else {
            0.0
        }
    }
}

/// Human-readable panic payload: downcasts the standard `&str` /
/// `String` payloads and [`InjectedFault`].
pub fn panic_message(p: &(dyn Any + Send)) -> String {
    if let Some(f) = p.downcast_ref::<InjectedFault>() {
        return format!("injected fault: {:?} for request {}", f.site, f.req_id);
    }
    if let Some(s) = p.downcast_ref::<&str>() {
        return (*s).to_string();
    }
    if let Some(s) = p.downcast_ref::<String>() {
        return s.clone();
    }
    "panic with non-string payload".to_string()
}

/// Install a panic hook that swallows [`InjectedFault`] payloads (the
/// chaos suite would otherwise spray hundreds of expected backtraces
/// onto stderr) while delegating every genuine panic to the previous
/// hook. Idempotent; safe to call from every chaos test.
pub fn silence_injected_panics() {
    use std::sync::Once;
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if info.payload().downcast_ref::<InjectedFault>().is_none() {
                prev(info);
            }
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decisions_are_deterministic_and_stateless() {
        let p = FaultPlan::seeded(42, 0.2);
        let a: Vec<bool> =
            (0..64).map(|s| p.should_fail(FaultSite::Decode, 7, s)).collect();
        // same plan, same keys, interleaved with unrelated rolls →
        // identical decisions (statelessness is what makes retried
        // rounds replayable)
        let q = FaultPlan::seeded(42, 0.2);
        let b: Vec<bool> = (0..64)
            .map(|s| {
                let _ = q.should_fail(FaultSite::Prefill, 99, s * 3);
                q.should_fail(FaultSite::Decode, 7, s)
            })
            .collect();
        assert_eq!(a, b);
    }

    #[test]
    fn zero_rate_never_fires_full_rate_always_fires() {
        let none = FaultPlan::none();
        let all = FaultPlan::seeded(1, 1.0);
        for s in 0..256 {
            assert!(!none.should_fail(FaultSite::Decode, s, s));
            assert!(all.should_fail(FaultSite::Alloc, s, s));
        }
        assert!(!none.enabled());
        assert!(all.enabled());
        assert_eq!(none.injected_latency_ms(5), 0.0);
        assert_eq!(all.injected_latency_ms(5), 3.0);
    }

    #[test]
    fn seeded_rate_is_roughly_honored() {
        let p = FaultPlan::seeded(3, 0.1);
        let hits = (0..4000)
            .filter(|&k| p.should_fail(FaultSite::Decode, k % 17, k / 17))
            .count();
        assert!(
            (200..800).contains(&hits),
            "rate 0.1 over 4000 keys fired {hits} times — mixer is biased"
        );
    }

    #[test]
    fn different_seeds_move_the_schedule() {
        let a = FaultPlan::seeded(1, 0.1);
        let b = FaultPlan::seeded(2, 0.1);
        let da: Vec<bool> =
            (0..512).map(|k| a.should_fail(FaultSite::Decode, k, 0)).collect();
        let db: Vec<bool> =
            (0..512).map(|k| b.should_fail(FaultSite::Decode, k, 0)).collect();
        assert_ne!(da, db, "seed must move the fault schedule");
    }

    #[test]
    fn targeted_fault_fires_exactly_at_its_key() {
        let p = FaultPlan {
            targeted: vec![TargetedFault { site: FaultSite::Decode, req_id: 3, step: 2 }],
            ..FaultPlan::none()
        };
        assert!(p.enabled());
        assert!(p.should_fail(FaultSite::Decode, 3, 2));
        assert!(!p.should_fail(FaultSite::Decode, 3, 1));
        assert!(!p.should_fail(FaultSite::Decode, 2, 2));
        assert!(!p.should_fail(FaultSite::Prefill, 3, 2));
    }

    #[test]
    fn spec_sites_are_independent_keys() {
        // Draft and Verify are distinct hash kinds: a plan targeting
        // one never fires the other, and seeded rates roll separate
        // decisions per site (ISSUE 10 chaos coverage)
        let p = FaultPlan {
            draft_panic: 1.0,
            ..FaultPlan::none()
        };
        assert!(p.enabled());
        assert!(p.should_fail(FaultSite::Draft, 3, 2));
        assert!(!p.should_fail(FaultSite::Verify, 3, 2));
        let t = FaultPlan {
            targeted: vec![TargetedFault { site: FaultSite::Verify, req_id: 5, step: 4 }],
            ..FaultPlan::none()
        };
        assert!(t.enabled());
        assert!(t.should_fail(FaultSite::Verify, 5, 4));
        assert!(!t.should_fail(FaultSite::Draft, 5, 4));
        assert!(!t.should_fail(FaultSite::Verify, 5, 3));
        let s = FaultPlan::seeded(11, 0.3);
        let da: Vec<bool> = (0..256).map(|k| s.should_fail(FaultSite::Draft, k, 0)).collect();
        let dv: Vec<bool> = (0..256).map(|k| s.should_fail(FaultSite::Verify, k, 0)).collect();
        assert_ne!(da, dv, "Draft and Verify must hash as different sites");
    }

    #[test]
    fn check_panics_with_attributable_payload() {
        let p = FaultPlan {
            targeted: vec![TargetedFault { site: FaultSite::Prefill, req_id: 9, step: 0 }],
            ..FaultPlan::none()
        };
        silence_injected_panics();
        let err = std::panic::catch_unwind(|| p.check(FaultSite::Prefill, 9, 0))
            .expect_err("targeted fault must panic");
        let f = err.downcast_ref::<InjectedFault>().expect("payload must be InjectedFault");
        assert_eq!(f.req_id, 9);
        assert_eq!(f.site, FaultSite::Prefill);
        assert!(panic_message(&*err).contains("request 9"));
    }

    #[test]
    fn panic_message_downcasts_standard_payloads() {
        let s: Box<dyn Any + Send> = Box::new("plain str");
        assert_eq!(panic_message(&*s), "plain str");
        let o: Box<dyn Any + Send> = Box::new(String::from("owned"));
        assert_eq!(panic_message(&*o), "owned");
        let x: Box<dyn Any + Send> = Box::new(17u32);
        assert_eq!(panic_message(&*x), "panic with non-string payload");
    }

    #[test]
    fn clock_default_is_wall() {
        assert_eq!(Clock::default(), Clock::Wall);
    }

    #[test]
    fn wall_anchor_is_monotone_nonnegative() {
        let a = WallAnchor::new();
        let t0 = a.elapsed_ms();
        let t1 = a.elapsed_ms();
        assert!(t0 >= 0.0);
        assert!(t1 >= t0, "anchor reads must be monotone: {t0} then {t1}");
    }
}
