//! Bucketed continuous batching for the decode loop.
//!
//! Decode executables are compiled AOT for a fixed set of batch sizes
//! (e.g. {1, 2, 4, 8}); each scheduler tick packs the active requests
//! into rounds drawn from those buckets, padding unused lanes (their
//! outputs are discarded by the state scatter). The packing minimizes
//! padded lanes over the whole tick. This is the SSM analog of vLLM's
//! continuous batching — with constant-size states there is no
//! fragmentation problem, so the packing is pure arithmetic.

/// Plan one scheduler tick: split `n_active` requests into rounds.
/// `buckets` must be sorted ascending. Returns bucket size per round,
/// largest first.
///
/// The plan is the *minimum-padding* cover: among all multisets of
/// buckets whose lane sum is ≥ `n_active`, pick the one with the
/// fewest total lanes, breaking ties by fewest rounds (each round is a
/// serial executable launch). The greedy "smallest bucket that fits
/// the remainder" heuristic gets this wrong — e.g. n=5 with buckets
/// {1,2,4,8} greedily packs one 8-round (37.5% padded lanes) when
/// [4,1] covers with zero waste.
pub fn plan_rounds(n_active: usize, buckets: &[usize]) -> Vec<usize> {
    assert!(!buckets.is_empty(), "no decode buckets available");
    debug_assert!(buckets.windows(2).all(|w| w[0] < w[1]));
    if n_active == 0 {
        return Vec::new();
    }
    // DP over the number of still-uncovered requests: best[k] is the
    // lexicographically minimal (lanes, rounds) covering k of them.
    const UNSET: (usize, usize) = (usize::MAX, usize::MAX);
    let mut best: Vec<(usize, usize)> = vec![UNSET; n_active + 1];
    let mut choice: Vec<usize> = vec![0; n_active + 1];
    best[0] = (0, 0);
    for k in 1..=n_active {
        for &b in buckets {
            let prev = best[k.saturating_sub(b)];
            if prev == UNSET {
                continue;
            }
            let cand = (prev.0 + b, prev.1 + 1);
            if cand < best[k] {
                best[k] = cand;
                choice[k] = b;
            }
        }
    }
    let mut rounds = Vec::with_capacity(best[n_active].1);
    let mut k = n_active;
    while k > 0 {
        let b = choice[k];
        rounds.push(b);
        k = k.saturating_sub(b);
    }
    // largest rounds first: fuller rounds run earliest, so harvesting
    // between rounds can only shrink later ones
    rounds.sort_unstable_by(|a, b| b.cmp(a));
    rounds
}

/// Padding overhead of a plan: padded lanes / total lanes.
pub fn padding_waste(n_active: usize, plan: &[usize]) -> f64 {
    let lanes: usize = plan.iter().sum();
    if lanes == 0 {
        return 0.0;
    }
    (lanes - n_active) as f64 / lanes as f64
}

/// Assign request indices to rounds following a plan.
pub fn assign(n_active: usize, plan: &[usize]) -> Vec<Vec<usize>> {
    let mut out = Vec::with_capacity(plan.len());
    let mut next = 0usize;
    for &b in plan {
        let take = b.min(n_active - next);
        out.push((next..next + take).collect());
        next += take;
    }
    assert_eq!(next, n_active, "plan does not cover all requests");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_fit() {
        assert_eq!(plan_rounds(8, &[1, 2, 4, 8]), vec![8]);
        assert_eq!(plan_rounds(4, &[1, 2, 4, 8]), vec![4]);
        assert_eq!(plan_rounds(1, &[1, 2, 4, 8]), vec![1]);
    }

    #[test]
    fn padding_cases() {
        // minimum-padding splits: zero waste whenever the bucket set
        // can compose the exact count
        assert_eq!(plan_rounds(3, &[1, 2, 4, 8]), vec![2, 1]);
        assert_eq!(plan_rounds(5, &[1, 2, 4, 8]), vec![4, 1]);
        assert_eq!(plan_rounds(7, &[1, 2, 4, 8]), vec![4, 2, 1]);
        assert!((padding_waste(5, &plan_rounds(5, &[1, 2, 4, 8])) - 0.0).abs() < 1e-12);
        // when padding is unavoidable, it is minimal: n=3 over {2,8}
        // wastes one lane ([2,2]), not five ([8])
        assert_eq!(plan_rounds(3, &[2, 8]), vec![2, 2]);
        // ties on lanes break toward fewer rounds
        assert_eq!(plan_rounds(4, &[1, 2, 4, 8]), vec![4]);
        assert_eq!(plan_rounds(8, &[1, 2, 4, 8]), vec![8]);
    }

    #[test]
    fn overflow_multiple_rounds() {
        assert_eq!(plan_rounds(17, &[1, 2, 4, 8]), vec![8, 8, 1]);
        assert_eq!(plan_rounds(10, &[1, 2, 4, 8]), vec![8, 2]);
        assert_eq!(plan_rounds(21, &[1, 2, 4, 8]), vec![8, 8, 4, 1]);
    }

    #[test]
    fn only_b1_available() {
        assert_eq!(plan_rounds(3, &[1]), vec![1, 1, 1]);
    }

    #[test]
    fn zero_active_empty_plan() {
        assert_eq!(plan_rounds(0, &[1, 2, 4, 8]), Vec::<usize>::new());
    }

    /// The greedy heuristic the planner replaced (kept as the
    /// property-test adversary).
    fn plan_rounds_greedy(n_active: usize, buckets: &[usize]) -> Vec<usize> {
        let max = *buckets.last().unwrap();
        let mut rounds = Vec::new();
        let mut left = n_active;
        while left > 0 {
            let take = left.min(max);
            let b = *buckets.iter().find(|&&b| b >= take).unwrap_or(&max);
            rounds.push(b);
            left -= take;
        }
        rounds
    }

    #[test]
    fn prop_never_wastes_more_than_greedy() {
        // seeded sweep over (n, bucket subset): the DP plan covers all
        // requests and never pads more lanes than the greedy plan
        let mut r = crate::util::rng::Pcg32::new(0xBA7C4);
        for _ in 0..500 {
            let n = 1 + r.below(64) as usize;
            let all = [1usize, 2, 3, 4, 8, 16];
            let mut buckets: Vec<usize> = all.iter().filter(|_| r.f32() < 0.5).cloned().collect();
            if buckets.is_empty() {
                buckets.push(1 + r.below(8) as usize);
            }
            let plan = plan_rounds(n, &buckets);
            let greedy = plan_rounds_greedy(n, &buckets);
            let lanes: usize = plan.iter().sum();
            let greedy_lanes: usize = greedy.iter().sum();
            assert!(lanes >= n, "plan {plan:?} does not cover n={n}");
            assert!(plan.iter().all(|b| buckets.contains(b)), "{plan:?} vs {buckets:?}");
            assert!(
                lanes <= greedy_lanes,
                "n={n} buckets={buckets:?}: dp {plan:?} wastes more than greedy {greedy:?}"
            );
            // and assignment still covers exactly n requests
            let covered: usize = assign(n, &plan).iter().map(|g| g.len()).sum();
            assert_eq!(covered, n);
        }
    }

    #[test]
    fn assign_covers_everything() {
        let plan = plan_rounds(10, &[1, 2, 4, 8]);
        let groups = assign(10, &plan);
        let all: Vec<usize> = groups.concat();
        assert_eq!(all, (0..10).collect::<Vec<_>>());
        for (g, &b) in groups.iter().zip(&plan) {
            assert!(g.len() <= b);
        }
    }
}
