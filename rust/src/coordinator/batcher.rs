//! Bucketed continuous batching for the decode loop.
//!
//! Decode executables are compiled AOT for a fixed set of batch sizes
//! (e.g. {1, 2, 4, 8}); each scheduler tick packs the active requests
//! into rounds: every round runs the smallest bucket that fits its
//! group, padding unused lanes (their outputs are discarded by the
//! state scatter). This is the SSM analog of vLLM's continuous
//! batching — with constant-size states there is no fragmentation
//! problem, so the packing is pure arithmetic.

/// Plan one scheduler tick: split `n_active` requests into rounds.
/// `buckets` must be sorted ascending. Returns bucket size per round.
pub fn plan_rounds(n_active: usize, buckets: &[usize]) -> Vec<usize> {
    assert!(!buckets.is_empty(), "no decode buckets available");
    debug_assert!(buckets.windows(2).all(|w| w[0] < w[1]));
    let max = *buckets.last().unwrap();
    let mut rounds = Vec::new();
    let mut left = n_active;
    while left > 0 {
        let take = left.min(max);
        // smallest bucket that fits `take`
        let b = *buckets.iter().find(|&&b| b >= take).unwrap_or(&max);
        rounds.push(b);
        left -= take;
    }
    rounds
}

/// Padding overhead of a plan: padded lanes / total lanes.
pub fn padding_waste(n_active: usize, plan: &[usize]) -> f64 {
    let lanes: usize = plan.iter().sum();
    if lanes == 0 {
        return 0.0;
    }
    (lanes - n_active) as f64 / lanes as f64
}

/// Assign request indices to rounds following a plan.
pub fn assign(n_active: usize, plan: &[usize]) -> Vec<Vec<usize>> {
    let mut out = Vec::with_capacity(plan.len());
    let mut next = 0usize;
    for &b in plan {
        let take = b.min(n_active - next);
        out.push((next..next + take).collect());
        next += take;
    }
    assert_eq!(next, n_active, "plan does not cover all requests");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_fit() {
        assert_eq!(plan_rounds(8, &[1, 2, 4, 8]), vec![8]);
        assert_eq!(plan_rounds(4, &[1, 2, 4, 8]), vec![4]);
        assert_eq!(plan_rounds(1, &[1, 2, 4, 8]), vec![1]);
    }

    #[test]
    fn padding_cases() {
        assert_eq!(plan_rounds(3, &[1, 2, 4, 8]), vec![4]); // 1 padded lane
        assert_eq!(plan_rounds(5, &[1, 2, 4, 8]), vec![8]); // 3 padded lanes
        assert!((padding_waste(5, &[8]) - 0.375).abs() < 1e-12);
    }

    #[test]
    fn overflow_multiple_rounds() {
        assert_eq!(plan_rounds(17, &[1, 2, 4, 8]), vec![8, 8, 1]);
        assert_eq!(plan_rounds(10, &[1, 2, 4, 8]), vec![8, 2]);
    }

    #[test]
    fn only_b1_available() {
        assert_eq!(plan_rounds(3, &[1]), vec![1, 1, 1]);
    }

    #[test]
    fn assign_covers_everything() {
        let plan = plan_rounds(10, &[1, 2, 4, 8]);
        let groups = assign(10, &plan);
        let all: Vec<usize> = groups.concat();
        assert_eq!(all, (0..10).collect::<Vec<_>>());
        for (g, &b) in groups.iter().zip(&plan) {
            assert!(g.len() <= b);
        }
    }
}
