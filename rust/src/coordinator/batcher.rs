//! Bucketed continuous batching for the decode loop, plus the unified
//! mixed decode+prefill tick planner.
//!
//! Decode executables are compiled AOT for a fixed set of batch sizes
//! (e.g. {1, 2, 4, 8}); each scheduler tick packs the active requests
//! into rounds drawn from those buckets, padding unused lanes (their
//! outputs are discarded by the state scatter). The packing minimizes
//! padded lanes over the whole tick. This is the SSM analog of vLLM's
//! continuous batching — with constant-size states there is no
//! fragmentation problem, so the packing is pure arithmetic.
//!
//! [`plan_tick`] generalizes the per-tick plan to **mixed** work: all
//! decode lanes (one token each — inter-token latency is the
//! protected quantity) plus prefill *chunks* (up to `prefill_chunk`
//! tokens per in-flight prompt) under one `max_tokens_per_tick`
//! budget, so a long prompt advances incrementally across ticks
//! instead of freezing every live lane while it prefills — the
//! standard chunked-prefill/continuous-batching shape, uniquely cheap
//! for SSMs because the recurrent state lets a prefill pause at any
//! token boundary for free.

/// Plan one scheduler tick: split `n_active` requests into rounds.
/// `buckets` must be sorted ascending. Returns bucket size per round,
/// largest first.
///
/// The plan is the *minimum-padding* cover: among all multisets of
/// buckets whose lane sum is ≥ `n_active`, pick the one with the
/// fewest total lanes, breaking ties by fewest rounds (each round is a
/// serial executable launch). The greedy "smallest bucket that fits
/// the remainder" heuristic gets this wrong — e.g. n=5 with buckets
/// {1,2,4,8} greedily packs one 8-round (37.5% padded lanes) when
/// [4,1] covers with zero waste.
pub fn plan_rounds(n_active: usize, buckets: &[usize]) -> Vec<usize> {
    assert!(!buckets.is_empty(), "no decode buckets available");
    debug_assert!(buckets.windows(2).all(|w| w[0] < w[1]));
    if n_active == 0 {
        return Vec::new();
    }
    // DP over the number of still-uncovered requests: best[k] is the
    // lexicographically minimal (lanes, rounds) covering k of them.
    const UNSET: (usize, usize) = (usize::MAX, usize::MAX);
    let mut best: Vec<(usize, usize)> = vec![UNSET; n_active + 1];
    let mut choice: Vec<usize> = vec![0; n_active + 1];
    best[0] = (0, 0);
    for k in 1..=n_active {
        for &b in buckets {
            let prev = best[k.saturating_sub(b)];
            if prev == UNSET {
                continue;
            }
            let cand = (prev.0 + b, prev.1 + 1);
            if cand < best[k] {
                best[k] = cand;
                choice[k] = b;
            }
        }
    }
    let mut rounds = Vec::with_capacity(best[n_active].1);
    let mut k = n_active;
    while k > 0 {
        let b = choice[k];
        rounds.push(b);
        k = k.saturating_sub(b);
    }
    // largest rounds first: fuller rounds run earliest, so harvesting
    // between rounds can only shrink later ones
    rounds.sort_unstable_by(|a, b| b.cmp(a));
    rounds
}

/// Padding overhead of a plan: padded lanes / total lanes.
pub fn padding_waste(n_active: usize, plan: &[usize]) -> f64 {
    let lanes: usize = plan.iter().sum();
    if lanes == 0 {
        return 0.0;
    }
    (lanes - n_active) as f64 / lanes as f64
}

/// One prefilling request's share of a tick: advance the request at
/// `idx` (position in the planner's `prefill_remaining` input, i.e.
/// admission order) by `tokens` prompt tokens.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkAssignment {
    pub idx: usize,
    pub tokens: usize,
}

/// One scheduler tick's mixed work plan: the decode rounds (bucket
/// sizes, from [`plan_rounds`]), the per-lane speculative draft grants
/// (`spec_ks[i]` ≤ the lane's ask), plus the prefill chunks that fit
/// the remaining token budget.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TickPlan {
    pub decode_rounds: Vec<usize>,
    /// Draft tokens granted per speculating lane, aligned with the
    /// planner's `spec_asks` input. A lane granted 0 still runs its
    /// baseline 1-token verify (= plain decode through the verify
    /// path), so speculation degrades under budget pressure instead of
    /// stalling.
    pub spec_ks: Vec<usize>,
    pub chunks: Vec<ChunkAssignment>,
}

impl TickPlan {
    /// Total prompt tokens this plan prefills.
    pub fn prefill_tokens(&self) -> usize {
        self.chunks.iter().map(|c| c.tokens).sum()
    }

    /// Total draft tokens granted across speculating lanes.
    pub fn spec_tokens(&self) -> usize {
        self.spec_ks.iter().sum()
    }
}

/// Token-budget cost of one *granted* draft token: one draft-model
/// step plus one extra target verify row. The speculating lane's
/// baseline verify row (the token plain decode would have produced)
/// is budgeted at 1 alongside decode lanes.
pub const SPEC_TOKEN_COST: usize = 2;

/// Plan one unified tick over `n_decode` plain decoding lanes, the
/// speculating lanes asking `spec_asks[i]` draft tokens each, and the
/// in-flight prefills with `prefill_remaining[i]` prompt tokens left
/// (admission order — FIFO gets budget first).
///
/// Budget semantics (`0` = unlimited for both knobs):
/// * every decode lane is always scheduled (1 token each) — decode is
///   the latency-critical work and there are at most `capacity` lanes;
/// * every speculating lane is likewise guaranteed its baseline
///   1-token verify (plain decode through the verify path), then draft
///   tokens are granted round-robin across lanes at [`SPEC_TOKEN_COST`]
///   each while budget lasts, capped at the lane's ask — a tight tick
///   spreads speculation thin rather than filling lane 0 first;
/// * prefill chunks share what is left of `max_tokens_per_tick` after
///   decode + speculation, each request taking
///   `min(prefill_chunk, remaining, budget_left)` in FIFO order;
/// * **minimum-progress guarantee**: while prefills are pending, one
///   token is reserved *before* draft granting, so speculation can
///   never spend the whole budget out from under them — the oldest
///   prefill always gets at least 1 token, even when decode +
///   speculation baselines alone exceed the budget. A saturated pool
///   can stretch a prefill, never livelock it.
///
/// Invariant (tested below): when `max_tokens_per_tick > 0`,
/// `SPEC_TOKEN_COST * plan.spec_tokens() + plan.prefill_tokens() <=
/// max(max_tokens_per_tick - n_decode - spec_asks.len(), 1)`, with the
/// `1` arm only under the minimum-progress guarantee.
pub fn plan_tick(
    n_decode: usize,
    spec_asks: &[usize],
    prefill_remaining: &[usize],
    buckets: &[usize],
    prefill_chunk: usize,
    max_tokens_per_tick: usize,
) -> TickPlan {
    let decode_rounds = plan_rounds(n_decode, buckets);
    let cap = if prefill_chunk == 0 { usize::MAX } else { prefill_chunk };
    let baseline = n_decode + spec_asks.len();
    let mut budget = if max_tokens_per_tick == 0 {
        usize::MAX
    } else {
        max_tokens_per_tick.saturating_sub(baseline)
    };
    // Reserve the minimum-progress token up front: draft grants must
    // not be able to spend the pending prefill's guaranteed token
    // (re-adding it AFTER granting keeps the tick within allowance —
    // a post-grant `budget = 1` bump on an exactly-consumed even
    // allowance would over-schedule by one).
    let pending_prefill = prefill_remaining.iter().any(|&r| r > 0);
    if pending_prefill {
        budget = budget.saturating_sub(1);
    }
    // draft-token grants, round-robin in waves of +1 per lane
    let mut spec_ks = vec![0usize; spec_asks.len()];
    let mut granting = true;
    while granting && budget >= SPEC_TOKEN_COST {
        granting = false;
        for (k, &ask) in spec_ks.iter_mut().zip(spec_asks) {
            if *k < ask && budget >= SPEC_TOKEN_COST {
                *k += 1;
                budget -= SPEC_TOKEN_COST;
                granting = true;
            }
        }
    }
    if pending_prefill {
        budget = budget.saturating_add(1);
    }
    let mut chunks = Vec::new();
    for (idx, &remaining) in prefill_remaining.iter().enumerate() {
        if budget == 0 {
            break;
        }
        if remaining == 0 {
            continue; // defensive: a drained prefill has nothing to schedule
        }
        let tokens = remaining.min(cap).min(budget);
        chunks.push(ChunkAssignment { idx, tokens });
        budget -= tokens;
    }
    TickPlan { decode_rounds, spec_ks, chunks }
}

/// Assign request indices to rounds following a plan.
pub fn assign(n_active: usize, plan: &[usize]) -> Vec<Vec<usize>> {
    let mut out = Vec::with_capacity(plan.len());
    let mut next = 0usize;
    for &b in plan {
        let take = b.min(n_active - next);
        out.push((next..next + take).collect());
        next += take;
    }
    assert_eq!(next, n_active, "plan does not cover all requests");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_fit() {
        assert_eq!(plan_rounds(8, &[1, 2, 4, 8]), vec![8]);
        assert_eq!(plan_rounds(4, &[1, 2, 4, 8]), vec![4]);
        assert_eq!(plan_rounds(1, &[1, 2, 4, 8]), vec![1]);
    }

    #[test]
    fn padding_cases() {
        // minimum-padding splits: zero waste whenever the bucket set
        // can compose the exact count
        assert_eq!(plan_rounds(3, &[1, 2, 4, 8]), vec![2, 1]);
        assert_eq!(plan_rounds(5, &[1, 2, 4, 8]), vec![4, 1]);
        assert_eq!(plan_rounds(7, &[1, 2, 4, 8]), vec![4, 2, 1]);
        assert!((padding_waste(5, &plan_rounds(5, &[1, 2, 4, 8])) - 0.0).abs() < 1e-12);
        // when padding is unavoidable, it is minimal: n=3 over {2,8}
        // wastes one lane ([2,2]), not five ([8])
        assert_eq!(plan_rounds(3, &[2, 8]), vec![2, 2]);
        // ties on lanes break toward fewer rounds
        assert_eq!(plan_rounds(4, &[1, 2, 4, 8]), vec![4]);
        assert_eq!(plan_rounds(8, &[1, 2, 4, 8]), vec![8]);
    }

    #[test]
    fn overflow_multiple_rounds() {
        assert_eq!(plan_rounds(17, &[1, 2, 4, 8]), vec![8, 8, 1]);
        assert_eq!(plan_rounds(10, &[1, 2, 4, 8]), vec![8, 2]);
        assert_eq!(plan_rounds(21, &[1, 2, 4, 8]), vec![8, 8, 4, 1]);
    }

    #[test]
    fn only_b1_available() {
        assert_eq!(plan_rounds(3, &[1]), vec![1, 1, 1]);
    }

    #[test]
    fn zero_active_empty_plan() {
        assert_eq!(plan_rounds(0, &[1, 2, 4, 8]), Vec::<usize>::new());
    }

    /// The greedy heuristic the planner replaced (kept as the
    /// property-test adversary).
    fn plan_rounds_greedy(n_active: usize, buckets: &[usize]) -> Vec<usize> {
        let max = *buckets.last().unwrap();
        let mut rounds = Vec::new();
        let mut left = n_active;
        while left > 0 {
            let take = left.min(max);
            let b = *buckets.iter().find(|&&b| b >= take).unwrap_or(&max);
            rounds.push(b);
            left -= take;
        }
        rounds
    }

    #[test]
    fn prop_never_wastes_more_than_greedy() {
        // seeded sweep over (n, bucket subset): the DP plan covers all
        // requests and never pads more lanes than the greedy plan
        let mut r = crate::util::rng::Pcg32::new(0xBA7C4);
        for _ in 0..500 {
            let n = 1 + r.below(64) as usize;
            let all = [1usize, 2, 3, 4, 8, 16];
            let mut buckets: Vec<usize> = all.iter().filter(|_| r.f32() < 0.5).cloned().collect();
            if buckets.is_empty() {
                buckets.push(1 + r.below(8) as usize);
            }
            let plan = plan_rounds(n, &buckets);
            let greedy = plan_rounds_greedy(n, &buckets);
            let lanes: usize = plan.iter().sum();
            let greedy_lanes: usize = greedy.iter().sum();
            assert!(lanes >= n, "plan {plan:?} does not cover n={n}");
            assert!(plan.iter().all(|b| buckets.contains(b)), "{plan:?} vs {buckets:?}");
            assert!(
                lanes <= greedy_lanes,
                "n={n} buckets={buckets:?}: dp {plan:?} wastes more than greedy {greedy:?}"
            );
            // and assignment still covers exactly n requests
            let covered: usize = assign(n, &plan).iter().map(|g| g.len()).sum();
            assert_eq!(covered, n);
        }
    }

    #[test]
    fn assign_covers_everything() {
        let plan = plan_rounds(10, &[1, 2, 4, 8]);
        let groups = assign(10, &plan);
        let all: Vec<usize> = groups.concat();
        assert_eq!(all, (0..10).collect::<Vec<_>>());
        for (g, &b) in groups.iter().zip(&plan) {
            assert!(g.len() <= b);
        }
    }

    // ---- degenerate inputs (ISSUE 5 satellite) ----

    #[test]
    #[should_panic(expected = "no decode buckets")]
    fn plan_rounds_rejects_empty_bucket_list() {
        let _ = plan_rounds(3, &[]);
    }

    #[test]
    #[should_panic(expected = "no decode buckets")]
    fn plan_tick_rejects_empty_bucket_list() {
        let _ = plan_tick(1, &[], &[4], &[], 0, 0);
    }

    #[test]
    fn buckets_larger_than_active_count_pad_minimally() {
        // every bucket exceeds n: one smallest-bucket round, padded
        assert_eq!(plan_rounds(3, &[8, 16]), vec![8]);
        assert_eq!(plan_rounds(1, &[4]), vec![4]);
        let groups = assign(3, &plan_rounds(3, &[8, 16]));
        assert_eq!(groups, vec![(0..3).collect::<Vec<_>>()]);
        // a lone oversized round keeps all real lanes in round 0
        let groups = assign(1, &plan_rounds(1, &[4]));
        assert_eq!(groups, vec![vec![0]]);
    }

    #[test]
    fn assign_tolerates_overcovering_plan() {
        // a plan whose lane sum exceeds n must park the excess as
        // padding, not panic or invent indices
        let groups = assign(5, &[4, 4]);
        assert_eq!(groups[0], vec![0, 1, 2, 3]);
        assert_eq!(groups[1], vec![4]);
    }

    // ---- mixed-plan planner ----

    #[test]
    fn plan_tick_unlimited_gives_full_chunks() {
        let p = plan_tick(3, &[], &[100, 5, 40], &[1, 2, 4, 8], 16, 0);
        assert_eq!(plan_rounds(3, &[1, 2, 4, 8]), p.decode_rounds);
        assert_eq!(
            p.chunks,
            vec![
                ChunkAssignment { idx: 0, tokens: 16 },
                ChunkAssignment { idx: 1, tokens: 5 },
                ChunkAssignment { idx: 2, tokens: 16 },
            ]
        );
    }

    #[test]
    fn plan_tick_unchunked_takes_whole_prompts() {
        let p = plan_tick(0, &[], &[100, 5], &[1, 2], 0, 0);
        assert!(p.decode_rounds.is_empty());
        assert_eq!(p.prefill_tokens(), 105);
    }

    #[test]
    fn plan_tick_budget_is_fifo_and_tight() {
        // budget 20, 4 decode lanes → 16 tokens for prefill, oldest first
        let p = plan_tick(4, &[], &[10, 10, 10], &[1, 2, 4, 8], 8, 20);
        assert_eq!(
            p.chunks,
            vec![
                ChunkAssignment { idx: 0, tokens: 8 },
                ChunkAssignment { idx: 1, tokens: 8 },
            ]
        );
        assert_eq!(p.prefill_tokens(), 16);
    }

    #[test]
    fn plan_tick_minimum_progress_under_decode_saturation() {
        // decode alone fills the budget: the oldest prefill still gets
        // exactly one token (no livelock), nothing else runs
        let p = plan_tick(8, &[], &[500, 500], &[1, 2, 4, 8], 64, 8);
        assert_eq!(p.chunks, vec![ChunkAssignment { idx: 0, tokens: 1 }]);
        // ...but an idle prefill queue adds nothing
        let p = plan_tick(8, &[], &[], &[1, 2, 4, 8], 64, 8);
        assert!(p.chunks.is_empty());
        let p = plan_tick(8, &[], &[0, 0], &[1, 2, 4, 8], 64, 8);
        assert!(p.chunks.is_empty(), "drained prefills must not trigger the guarantee");
    }

    #[test]
    fn prop_plan_tick_token_budget_invariant() {
        // seeded sweep: the mixed plan never over-schedules — spec
        // grants (at SPEC_TOKEN_COST each) plus prefill tokens fit
        // max(budget − n_decode − n_spec, 1), grants respect per-lane
        // asks, chunks respect the per-request cap and remaining
        // counts, FIFO order, ≤ 1 chunk per request — and always makes
        // progress when work exists
        let mut r = crate::util::rng::Pcg32::new(0x71C4);
        for _ in 0..1000 {
            let n_decode = r.below(12) as usize;
            let n_spec = r.below(5) as usize;
            let asks: Vec<usize> = (0..n_spec).map(|_| r.below(9) as usize).collect();
            let n_pf = r.below(6) as usize;
            let remaining: Vec<usize> = (0..n_pf).map(|_| 1 + r.below(300) as usize).collect();
            let chunk = if r.f32() < 0.3 { 0 } else { 1 + r.below(64) as usize };
            let budget = if r.f32() < 0.3 { 0 } else { 1 + r.below(40) as usize };
            let p = plan_tick(n_decode, &asks, &remaining, &[1, 2, 4, 8], chunk, budget);
            // decode side: covers every decoding lane
            let lanes: usize = p.decode_rounds.iter().sum();
            assert!(lanes >= n_decode);
            // spec side: one grant slot per lane, capped by its ask
            assert_eq!(p.spec_ks.len(), asks.len());
            for (k, ask) in p.spec_ks.iter().zip(&asks) {
                assert!(k <= ask, "grant {k} exceeds ask {ask}");
            }
            if budget == 0 {
                assert_eq!(p.spec_ks, asks, "unlimited budget must grant full asks");
            }
            // chunk-shape invariants
            let mut last_idx = None;
            for c in &p.chunks {
                assert!(c.tokens > 0);
                assert!(c.tokens <= remaining[c.idx]);
                if chunk > 0 {
                    assert!(c.tokens <= chunk);
                }
                if let Some(prev) = last_idx {
                    assert!(c.idx > prev, "chunks must be FIFO and at most one per request");
                }
                last_idx = Some(c.idx);
            }
            // budget invariant
            if budget > 0 {
                let allowance = budget.saturating_sub(n_decode + asks.len()).max(1);
                assert!(
                    SPEC_TOKEN_COST * p.spec_tokens() + p.prefill_tokens() <= allowance,
                    "n_decode={n_decode} asks={asks:?} budget={budget} chunk={chunk} \
                     remaining={remaining:?} plan={p:?}"
                );
            }
            // liveness: pending prefill always advances
            if !remaining.is_empty() {
                assert!(p.prefill_tokens() >= 1, "prefill starved: {p:?}");
            }
        }
    }

    // ---- speculative-lane grants ----

    #[test]
    fn plan_tick_spec_unlimited_grants_full_asks() {
        let p = plan_tick(2, &[4, 0, 8], &[], &[1, 2, 4, 8], 0, 0);
        assert_eq!(p.spec_ks, vec![4, 0, 8]);
        assert_eq!(p.spec_tokens(), 12);
    }

    #[test]
    fn plan_tick_spec_grants_are_round_robin_under_pressure() {
        // budget 13, 1 decode + 2 spec lanes → baseline 3, 10 left →
        // 5 grants of cost 2 spread in waves: [3, 2], not [4, 1]
        let p = plan_tick(1, &[4, 4], &[], &[1, 2, 4, 8], 0, 13);
        assert_eq!(p.spec_ks, vec![3, 2]);
        // an exhausted ask releases its wave slot to the others
        let p = plan_tick(1, &[1, 4], &[], &[1, 2, 4, 8], 0, 13);
        assert_eq!(p.spec_ks, vec![1, 4]);
    }

    #[test]
    fn plan_tick_spec_baseline_always_scheduled() {
        // budget ≤ baseline: every spec lane still verifies 1 token
        // (k=0 = plain decode through the verify path), prefill keeps
        // its minimum-progress token
        let p = plan_tick(4, &[8, 8], &[100], &[1, 2, 4, 8], 16, 6);
        assert_eq!(p.spec_ks, vec![0, 0]);
        assert_eq!(p.chunks, vec![ChunkAssignment { idx: 0, tokens: 1 }]);
    }

    #[test]
    fn plan_tick_spec_leaves_leftover_budget_to_prefill() {
        // budget 12, 1 decode + 1 spec(ask 2) → baseline 2, grants eat
        // 4, prefill gets the remaining 6
        let p = plan_tick(1, &[2], &[100], &[1, 2, 4, 8], 64, 12);
        assert_eq!(p.spec_ks, vec![2]);
        assert_eq!(p.chunks, vec![ChunkAssignment { idx: 0, tokens: 6 }]);
    }
}
