//! Transformer serving engine — the KV-cache comparator to [`super::engine`].
//!
//! Exists so the Figure 1(b)/1(c) comparisons run through the *same
//! coordinator abstractions* rather than hand-rolled loops: requests
//! are admitted against the KV pool's byte watermark (backpressure),
//! each holds a growing (L, max_ctx, H, Dh) K/V slab, and decode steps
//! thread the cache through the AOT graph with an explicit position.
//!
//! Single-lane decode (the transformer artifacts ship B=1 graphs; the
//! KV-gather cost of batched decode on a host-roundtrip runtime would
//! measure the harness, not the model — noted in DESIGN.md §8).

use std::collections::VecDeque;

use anyhow::{anyhow, Result};

use crate::config::TransformerTierInfo;
use crate::coordinator::faults::WallAnchor;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{LiveRequest, Phase, Request, Response};
use crate::coordinator::sampler::Sampler;
use crate::data::BOS;
use crate::runtime::{lit_from_f32, lit_from_i32, lit_to_f32, Runtime};

pub struct TransformerEngine {
    pub tier: TransformerTierInfo,
    pub method: String,
    pub rt: Runtime,
    queue: VecDeque<Request>,
    /// (request, K cache, V cache, live length)
    live: Vec<(LiveRequest, Vec<f32>, Vec<f32>, usize)>,
    done: Vec<Response>,
    sampler: Sampler,
    pub metrics: Metrics,
    prefill_graph: String,
    prefill_len: usize,
    decode_graph: String,
    vocab: usize,
    /// KV byte budget across live requests (backpressure watermark)
    pub byte_budget: usize,
    /// engine clock zero — the only wall-time source here
    /// (clock-discipline audit rule)
    anchor: WallAnchor,
}

impl TransformerEngine {
    pub fn new(rt: Runtime, tier: &str, method: &str, byte_budget: usize) -> Result<Self> {
        let tinfo = rt
            .manifest()
            .transformer_tiers
            .get(tier)
            .ok_or_else(|| anyhow!("unknown transformer tier {tier}"))?
            .clone();
        let pf = rt
            .manifest()
            .graphs
            .values()
            .filter(|g| g.tier == tier && g.method == method && g.kind == "prefill" && g.batch == 1)
            .min_by_key(|g| g.seq)
            .ok_or_else(|| anyhow!("no transformer prefill graph"))?;
        let prefill_graph = pf.name.clone();
        let prefill_len = pf.seq;
        let decode_graph = rt
            .manifest()
            .find_graph(tier, method, "decode", 1, None)
            .ok_or_else(|| anyhow!("no transformer decode graph"))?
            .name
            .clone();
        let vocab = rt.manifest().vocab_size;
        Ok(TransformerEngine {
            tier: tinfo,
            method: method.to_string(),
            rt,
            queue: VecDeque::new(),
            live: Vec::new(),
            done: Vec::new(),
            sampler: Sampler::new(super::engine::DEFAULT_SAMPLER_SEED),
            metrics: Metrics::new(),
            prefill_graph,
            prefill_len,
            decode_graph,
            vocab,
            byte_budget,
            anchor: WallAnchor::new(),
        })
    }

    /// Re-seed the token sampler (this engine has no config struct;
    /// the SSM engines take the seed via `EngineConfig` /
    /// `NativeEngineConfig`). Call before serving for reproducibility.
    pub fn set_sampler_seed(&mut self, seed: u64) {
        self.sampler = Sampler::new(seed);
    }

    pub fn submit(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    pub fn n_live(&self) -> usize {
        self.live.len()
    }

    pub fn n_queued(&self) -> usize {
        self.queue.len()
    }

    fn cache_elems(&self) -> usize {
        let t = &self.tier;
        t.n_layer * t.max_ctx * t.n_head * (t.d_model / t.n_head)
    }

    /// Bytes a live request holds at context length `ctx` (K + V).
    pub fn bytes_at(&self, ctx: usize) -> usize {
        let t = &self.tier;
        2 * 4 * t.n_layer * t.n_head * (t.d_model / t.n_head) * ctx
    }

    fn live_bytes(&self) -> usize {
        self.live.iter().map(|(_, _, _, len)| self.bytes_at(*len)).sum()
    }

    /// One scheduler tick: admit while the KV watermark allows, then
    /// one decode step per live request.
    pub fn step(&mut self) -> Result<Vec<Response>> {
        while let Some(req) = self.queue.front() {
            let need = self.bytes_at(req.prompt.len().min(self.prefill_len) + req.max_new_tokens);
            if self.live_bytes() + need > self.byte_budget && !self.live.is_empty() {
                break; // backpressure: keep queued until KV frees up
            }
            let req = self.queue.pop_front().unwrap();
            self.prefill(req)?;
        }
        // decode one token per live request
        for idx in 0..self.live.len() {
            self.decode_one(idx)?;
        }
        // harvest
        let mut finished = Vec::new();
        let now = self.anchor.elapsed_ms();
        let mut i = 0;
        while i < self.live.len() {
            if self.live[i].0.done() {
                let (lr, _, _, _) = self.live.swap_remove(i);
                let resp = lr.into_response(now);
                self.metrics.record_response(resp.ttft_ms, resp.tpot_ms, resp.ttlt_ms,
                                             resp.tokens.len(), &resp.itl_ms);
                finished.push(resp);
            } else {
                i += 1;
            }
        }
        self.done.extend(finished.iter().cloned());
        Ok(finished)
    }

    pub fn run_to_completion(&mut self) -> Result<Vec<Response>> {
        while !self.queue.is_empty() || !self.live.is_empty() {
            self.step()?;
        }
        Ok(std::mem::take(&mut self.done))
    }

    fn prefill(&mut self, req: Request) -> Result<()> {
        let t = self.prefill_len;
        let prompt: Vec<u16> = if req.prompt.len() > t {
            req.prompt[req.prompt.len() - t..].to_vec()
        } else {
            let mut p = vec![BOS; t - req.prompt.len()];
            p.extend_from_slice(&req.prompt);
            p
        };
        let toks: Vec<i32> = prompt.iter().map(|&x| x as i32).collect();
        // per-request RNG stream unused here (this engine keeps its
        // shared sampler; `set_sampler_seed` predates the config route)
        let mut lr = LiveRequest::new(req, usize::MAX, super::engine::DEFAULT_SAMPLER_SEED);
        lr.submitted_ms = self.anchor.elapsed_ms();
        lr.admitted_ms = lr.submitted_ms;
        let n = self.cache_elems();
        let sh = self.cache_shape();
        let t0 = WallAnchor::new();
        let inputs = [
            lit_from_i32(&[1, t], &toks)?,
            lit_from_f32(&sh, &vec![0.0; n])?,
            lit_from_f32(&sh, &vec![0.0; n])?,
            lit_from_i32(&[], &[0])?,
        ];
        let g = self.prefill_graph.clone();
        let out = self.rt.execute_lit(&g, &inputs)?;
        self.metrics.prefill_ms.record(t0.elapsed_ms());
        let logits = lit_to_f32(&out[0])?;
        let k = lit_to_f32(&out[1])?;
        let v = lit_to_f32(&out[2])?;
        let vdim = logits.len() / t;
        let row = &logits[(t - 1) * vdim..t * vdim];
        let tok = self.sampler.sample(row, self.vocab, &lr.req.params);
        lr.generated.push(tok);
        lr.phase = Phase::Decoding;
        lr.prefill_done_ms = Some(self.anchor.elapsed_ms());
        lr.last_token_ms = lr.prefill_done_ms;
        self.live.push((lr, k, v, t));
        Ok(())
    }

    fn cache_shape(&self) -> Vec<usize> {
        let t = &self.tier;
        vec![t.n_layer, 1, t.max_ctx, t.n_head, t.d_model / t.n_head]
    }

    fn decode_one(&mut self, idx: usize) -> Result<()> {
        let sh = self.cache_shape();
        let (tok, pos, k, v) = {
            let (lr, k, v, len) = &self.live[idx];
            (lr.next_input_token() as i32, (*len).min(self.tier.max_ctx - 1), k.clone(), v.clone())
        };
        let inputs = [
            lit_from_i32(&[1, 1], &[tok])?,
            lit_from_f32(&sh, &k)?,
            lit_from_f32(&sh, &v)?,
            lit_from_i32(&[], &[pos as i32])?,
        ];
        let g = self.decode_graph.clone();
        let t0 = WallAnchor::new();
        let out = self.rt.execute_lit(&g, &inputs)?;
        self.metrics.decode_step_ms.record(t0.elapsed_ms());
        let logits = lit_to_f32(&out[0])?;
        let now = self.anchor.elapsed_ms();
        let (lr, kc, vc, len) = &mut self.live[idx];
        *kc = lit_to_f32(&out[1])?;
        *vc = lit_to_f32(&out[2])?;
        *len = (*len + 1).min(self.tier.max_ctx);
        let next = self.sampler.sample(&logits, self.vocab, &lr.req.params);
        lr.generated.push(next);
        if let Some(last) = lr.last_token_ms {
            lr.decode_ms.push(now - last);
        }
        lr.last_token_ms = Some(now);
        Ok(())
    }
}
