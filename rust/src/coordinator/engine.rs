//! The execution engine: single-owner loop over the PJRT runtime.
//!
//! One `Engine` owns the `Runtime` (PJRT client is not `Send`), the
//! SSM state pool, the admission queue, and the decode batcher. The
//! scheduler is prefill-priority: new requests are prefilled one at a
//! time (B=1 graph, left-padded to the graph length — every method
//! sees the identical treatment, so comparisons stay fair), then join
//! the continuous-batching decode pool, which packs live requests into
//! bucketed decode rounds each tick.

use std::collections::VecDeque;

use anyhow::{anyhow, Result};

use crate::cache::{CacheStats, PrefixCache, PrefixCacheConfig, Snapshot};
use crate::config::Manifest;
use crate::coordinator::batcher;
use crate::coordinator::faults::WallAnchor;
use crate::coordinator::metrics::{Metrics, MetricsSnapshot};
use crate::coordinator::request::{LiveRequest, Phase, Request, Response};
use crate::coordinator::sampler::Sampler;
use crate::coordinator::state::{SsmSlab, SsmStatePool};
use crate::data::BOS;
use crate::runtime::Runtime;
use crate::tensor::Tensor;

/// Default sampler seed shared by every engine flavor; override via
/// the `sampler_seed` config fields (determinism across engines is
/// seed-keyed — see `rust/src/coordinator/native.rs` tests).
pub const DEFAULT_SAMPLER_SEED: u64 = 0xC0FFEE;

#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub tier: String,
    pub method: String,
    /// state-pool capacity (max concurrent requests)
    pub capacity: usize,
    /// admission limit per tick
    pub max_prefills_per_tick: usize,
    /// seed for the token sampler RNG
    pub sampler_seed: u64,
    /// prefix-cache byte budget; 0 (default) disables it. The XLA
    /// engine's prefill graphs are fixed-length and left-padded, so a
    /// partial prefix cannot be replayed bit-exactly (the pad count
    /// would differ) — this engine reuses **exact whole-prompt** hits
    /// only: snapshot = end-of-prompt state + last logits row, hit =
    /// restore + sample, no graph execution at all. The native engine
    /// (`super::native`) does true longest-prefix reuse.
    pub cache_bytes: usize,
    /// accepted for config parity with [`super::native::NativeEngineConfig`];
    /// ignored here (exact-only reuse has no interior cut points).
    pub snapshot_stride: usize,
}

impl EngineConfig {
    pub fn new(tier: &str, method: &str) -> Self {
        EngineConfig {
            tier: tier.to_string(),
            method: method.to_string(),
            capacity: 32,
            max_prefills_per_tick: 2,
            sampler_seed: DEFAULT_SAMPLER_SEED,
            cache_bytes: 0,
            snapshot_stride: 0,
        }
    }
}

pub struct Engine {
    pub cfg: EngineConfig,
    pub rt: Runtime,
    pool: SsmStatePool,
    queue: VecDeque<Request>,
    live: Vec<LiveRequest>,
    done: Vec<Response>,
    sampler: Sampler,
    pub metrics: Metrics,
    decode_buckets: Vec<usize>,
    prefill_graph: String,
    prefill_len: usize,
    vocab: usize,
    /// exact-prompt snapshot cache (`cfg.cache_bytes > 0`)
    cache: Option<PrefixCache>,
    /// engine clock zero: every request stamp (`submitted_ms`,
    /// `prefill_done_ms`, ITL gaps) is ms since this anchor — the only
    /// wall-time source (clock-discipline audit rule)
    anchor: WallAnchor,
}

impl Engine {
    pub fn new(rt: Runtime, cfg: EngineConfig) -> Result<Engine> {
        let mani = rt.manifest();
        let tier = mani
            .tiers
            .get(&cfg.tier)
            .ok_or_else(|| anyhow!("unknown tier {}", cfg.tier))?
            .clone();
        // discover decode buckets for this (tier, method)
        let mut buckets: Vec<usize> = mani
            .graphs
            .values()
            .filter(|g| g.tier == cfg.tier && g.method == cfg.method && g.kind == "decode")
            .map(|g| g.batch)
            .collect();
        buckets.sort_unstable();
        buckets.dedup();
        if buckets.is_empty() {
            return Err(anyhow!("no decode graphs for {}/{}", cfg.tier, cfg.method));
        }
        // B=1 prefill with the smallest T (shortest latency for short prompts)
        let pf = mani
            .graphs
            .values()
            .filter(|g| {
                g.tier == cfg.tier && g.method == cfg.method && g.kind == "prefill" && g.batch == 1
            })
            .min_by_key(|g| g.seq)
            .ok_or_else(|| anyhow!("no prefill graph for {}/{}", cfg.tier, cfg.method))?;
        let prefill_graph = pf.name.clone();
        let prefill_len = pf.seq;
        let vocab = mani.vocab_size;
        let pool = SsmStatePool::new(&tier, cfg.capacity);
        let cache = (cfg.cache_bytes > 0).then(|| {
            PrefixCache::new(PrefixCacheConfig {
                capacity_bytes: cfg.cache_bytes,
                snapshot_stride: 0, // exact-only reuse: no cut points
            })
        });
        Ok(Engine {
            pool,
            queue: VecDeque::new(),
            live: Vec::new(),
            done: Vec::new(),
            sampler: Sampler::new(cfg.sampler_seed),
            metrics: Metrics::new(),
            decode_buckets: buckets,
            prefill_graph,
            prefill_len,
            vocab,
            cache,
            anchor: WallAnchor::new(),
            rt,
            cfg,
        })
    }

    /// Prefix-cache counters; `None` when serving with the cache off.
    pub fn cache_stats(&self) -> Option<CacheStats> {
        self.cache.as_ref().map(|c| c.stats())
    }

    /// Typed metrics snapshot stamped with the engine clock.
    pub fn metrics_snapshot(&self) -> MetricsSnapshot {
        self.metrics.snapshot(self.anchor.elapsed_ms())
    }

    pub fn manifest(&self) -> &Manifest {
        self.rt.manifest()
    }

    pub fn decode_buckets(&self) -> &[usize] {
        &self.decode_buckets
    }

    /// Pre-compile the graphs this engine will use (avoids paying the
    /// one-time XLA compile inside latency measurements).
    pub fn warmup(&mut self) -> Result<()> {
        let g = self.prefill_graph.clone();
        self.rt.load(&g)?;
        for b in self.decode_buckets.clone() {
            let name = self.decode_graph_name(b)?;
            self.rt.load(&name)?;
        }
        Ok(())
    }

    pub fn submit(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    pub fn n_queued(&self) -> usize {
        self.queue.len()
    }

    pub fn n_live(&self) -> usize {
        self.live.len()
    }

    pub fn state_bytes_per_request(&self) -> usize {
        self.pool.bytes_per_request()
    }

    /// Tokens generated so far (live requests + completed).
    pub fn tokens_generated(&self) -> usize {
        self.live.iter().map(|lr| lr.generated.len()).sum::<usize>()
            + self.metrics.tokens_out as usize
    }

    fn decode_graph_name(&self, b: usize) -> Result<String> {
        self.rt
            .manifest()
            .find_graph(&self.cfg.tier, &self.cfg.method, "decode", b, None)
            .map(|g| g.name.clone())
            .ok_or_else(|| anyhow!("no decode graph b={b}"))
    }

    /// Run one scheduler tick: admit + prefill a few queued requests,
    /// then one decode round over all live requests. Returns finished
    /// responses (also retained in `take_done`).
    pub fn step(&mut self) -> Result<Vec<Response>> {
        // -- admission + prefill --
        for _ in 0..self.cfg.max_prefills_per_tick {
            if self.queue.is_empty() || self.pool.in_use() >= self.pool.capacity() {
                break;
            }
            let req = self.queue.pop_front().unwrap();
            self.prefill(req)?;
        }
        // -- decode round(s) --
        if !self.live.is_empty() {
            self.decode_tick()?;
        }
        // -- harvest --
        let mut finished = Vec::new();
        let now = self.anchor.elapsed_ms();
        let mut i = 0;
        while i < self.live.len() {
            if self.live[i].done() {
                let lr = self.live.swap_remove(i);
                self.pool.release(lr.state_slot);
                let resp = lr.into_response(now);
                self.metrics.record_response(
                    resp.ttft_ms,
                    resp.tpot_ms,
                    resp.ttlt_ms,
                    resp.tokens.len(),
                    &resp.itl_ms,
                );
                finished.push(resp);
            } else {
                i += 1;
            }
        }
        self.done.extend(finished.iter().cloned());
        Ok(finished)
    }

    /// Drive until everything queued + live has finished.
    pub fn run_to_completion(&mut self) -> Result<Vec<Response>> {
        while !self.queue.is_empty() || !self.live.is_empty() {
            self.step()?;
        }
        Ok(std::mem::take(&mut self.done))
    }

    pub fn take_done(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.done)
    }

    fn prefill(&mut self, req: Request) -> Result<()> {
        let slot = self
            .pool
            .alloc()
            .ok_or_else(|| anyhow!("state pool exhausted"))?;
        let t = self.prefill_len;
        // the effective prompt (the last ≤ t tokens) is what the graph
        // actually computes on — and therefore the cache key: requests
        // with equal effective prompts share identical padded inputs
        let effective: Vec<u16> = if req.prompt.len() > t {
            req.prompt[req.prompt.len() - t..].to_vec()
        } else {
            req.prompt.clone()
        };
        let use_cache =
            self.cache.is_some() && !req.params.no_cache && !effective.is_empty();
        // this engine prefills whole prompts inline (fixed-length AOT
        // graphs cannot pause mid-prompt), so the request enters the
        // decode phase within this call; its per-request RNG stream is
        // seeded but unused — the XLA scheduler never reorders sampling
        // for a fixed workload, so the shared sampler stays exact here
        let mut lr = LiveRequest::new(req, slot, self.cfg.sampler_seed);
        // prefill runs inline at admission here, so queued and admitted
        // coincide on this engine's timeline
        lr.submitted_ms = self.anchor.elapsed_ms();
        lr.admitted_ms = lr.submitted_ms;
        let t0 = WallAnchor::new();
        // exact whole-prompt hit: restore the end-of-prompt state and
        // sample from the cached last logits row — no graph execution.
        // (Partial prefixes are not replayable here: the fixed-length
        // graph would left-pad the suffix with a different BOS count
        // than the cold run saw, changing the state bit pattern.)
        let hit =
            if use_cache { self.cache.as_mut().unwrap().lookup_exact(&effective) } else { None };
        if let Some(h) = hit {
            // lookup_exact only returns logits-bearing whole-prompt
            // entries; if that invariant ever drifts, fall through to
            // a cold prefill instead of panicking the serving thread
            if let Some(row) = h.logits_row {
                self.pool.write(slot, h.slab);
                self.metrics.prefill_ms.record(t0.elapsed_ms());
                let stats = self.cache.as_ref().unwrap().stats();
                self.metrics.record_cache_stats(stats);
                let tok = self.sampler.sample(&row, self.vocab, &lr.req.params);
                lr.generated.push(tok);
                lr.phase = Phase::Decoding;
                lr.prefill_done_ms = Some(self.anchor.elapsed_ms());
                lr.last_token_ms = lr.prefill_done_ms;
                self.live.push(lr);
                return Ok(());
            }
        }
        // left-pad with BOS to the graph length
        let mut prompt = vec![BOS; t - effective.len()];
        prompt.extend_from_slice(&effective);
        let toks: Vec<i32> = prompt.iter().map(|&x| x as i32).collect();
        let (cs, ss) = self.state_shapes(1);
        let inputs = [
            crate::runtime::lit_from_i32(&[1, t], &toks)?,
            crate::runtime::lit_from_f32(&cs, &vec![0.0; cs.iter().product()])?,
            crate::runtime::lit_from_f32(&ss, &vec![0.0; ss.iter().product()])?,
        ];
        let g = self.prefill_graph.clone();
        let out = self.rt.execute_lit(&g, &inputs)?;
        self.metrics.prefill_ms.record(t0.elapsed_ms());
        let (logits, conv, ssm) = unpack3_lit(&out)?;
        // store state
        self.pool.scatter_raw(&[slot], 1, &conv, &ssm);
        // first token from the last position
        let v = self.vocab_dim(&out[0], t)?;
        let row = &logits[(t - 1) * v..t * v];
        if use_cache {
            let snap = Snapshot {
                slab: self.pool.snapshot(slot),
                logits_row: Some(row.to_vec()),
            };
            let c = self.cache.as_mut().unwrap();
            c.insert(&effective, snap);
            let stats = c.stats();
            self.metrics.record_cache_stats(stats);
        }
        let tok = self.sampler.sample(row, self.vocab, &lr.req.params);
        lr.generated.push(tok);
        lr.phase = Phase::Decoding;
        lr.prefill_done_ms = Some(self.anchor.elapsed_ms());
        lr.last_token_ms = lr.prefill_done_ms;
        self.live.push(lr);
        Ok(())
    }

    fn state_shapes(&self, b: usize) -> (Vec<usize>, Vec<usize>) {
        let l = self.pool.n_layer;
        let di = self.pool.d_inner;
        let w1 = self.pool.conv_per_layer / di;
        let n = self.pool.ssm_per_layer / di;
        (vec![l, b, w1, di], vec![l, b, di, n])
    }

    fn vocab_dim(&self, logits: &xla::Literal, rows: usize) -> Result<usize> {
        let n = logits.element_count();
        if n % rows != 0 {
            return Err(anyhow!("logits size {n} not divisible by {rows}"));
        }
        Ok(n / rows)
    }

    fn decode_tick(&mut self) -> Result<()> {
        let n = self.live.len();
        let plan = batcher::plan_rounds(n, &self.decode_buckets);
        let groups = batcher::assign(n, &plan);
        for (gi, group) in groups.iter().enumerate() {
            let b = plan[gi];
            self.metrics.record_round(b, group.len());
            self.decode_round(group, b)?;
        }
        Ok(())
    }

    fn decode_round(&mut self, group: &[usize], b: usize) -> Result<()> {
        let slots: Vec<usize> = group.iter().map(|&i| self.live[i].state_slot).collect();
        let (conv, ssm) = self.pool.gather_raw(&slots, b);
        let mut toks = vec![0i32; b];
        for (bi, &i) in group.iter().enumerate() {
            toks[bi] = self.live[i].next_input_token() as i32;
        }
        let (cs, ss) = self.state_shapes(b);
        let inputs = [
            crate::runtime::lit_from_i32(&[b, 1], &toks)?,
            crate::runtime::lit_from_f32(&cs, &conv)?,
            crate::runtime::lit_from_f32(&ss, &ssm)?,
        ];
        let graph = self.decode_graph_name(b)?;
        let t0 = WallAnchor::new();
        let out = self.rt.execute_lit(&graph, &inputs)?;
        self.metrics.decode_step_ms.record(t0.elapsed_ms());
        let (logits, conv_o, ssm_o) = unpack3_lit(&out)?;
        self.pool.scatter_raw(&slots, b, &conv_o, &ssm_o);
        let v = logits.len() / b;
        let now = self.anchor.elapsed_ms();
        for (bi, &i) in group.iter().enumerate() {
            let row = &logits[bi * v..(bi + 1) * v];
            let lr = &mut self.live[i];
            let tok = self.sampler.sample(row, self.vocab, &lr.req.params);
            lr.generated.push(tok);
            if let Some(last) = lr.last_token_ms {
                lr.decode_ms.push(now - last);
            }
            lr.last_token_ms = Some(now);
        }
        Ok(())
    }
}

/// (logits, conv, ssm) as raw f32 vectors from a 3-output literal set.
fn unpack3_lit(out: &[xla::Literal]) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
    if out.len() != 3 {
        return Err(anyhow!("expected 3 outputs, got {}", out.len()));
    }
    Ok((
        crate::runtime::lit_to_f32(&out[0])?,
        crate::runtime::lit_to_f32(&out[1])?,
        crate::runtime::lit_to_f32(&out[2])?,
    ))
}

// allow the state pool to accept slabs from prefill via scatter
impl SsmStatePool {
    /// Build a slab directly from (L,1,...) prefill state tensors.
    pub fn slab_from_tensors(&self, conv: &Tensor, ssm: &Tensor) -> SsmSlab {
        SsmSlab { conv: conv.to_f32(), conv_q: Vec::new(), ssm: ssm.to_f32() }
    }
}
