//! Threaded serving front door.
//!
//! tokio is not in the offline vendor set — and one executor thread is
//! the natural shape for one PJRT CPU device — so the server is a
//! dedicated engine thread plus std::mpsc channels: clients submit
//! requests with a response channel and block (or poll) on it. This is
//! the same single-owner architecture a GPU-stream-bound executor uses.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use anyhow::Result;

use crate::coordinator::engine::{Engine, EngineConfig};
use crate::coordinator::request::{Request, RequestId, Response, SamplingParams};
use crate::runtime::Runtime;

enum Msg {
    Submit(Request, Sender<Response>),
    Report(Sender<String>),
    Shutdown,
}

pub struct ServerHandle {
    tx: Sender<Msg>,
    join: Option<JoinHandle<()>>,
    next_id: RequestId,
}

impl ServerHandle {
    /// Spawn the engine thread. The `Runtime` is constructed *inside*
    /// the thread (PJRT client is not Send).
    pub fn spawn(artifacts_root: std::path::PathBuf, cfg: EngineConfig) -> Result<ServerHandle> {
        let (tx, rx): (Sender<Msg>, Receiver<Msg>) = channel();
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let join = std::thread::Builder::new()
            .name("quamba-engine".into())
            .spawn(move || {
                let rt = match Runtime::new(&artifacts_root) {
                    Ok(rt) => rt,
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                let mut engine = match Engine::new(rt, cfg) {
                    Ok(e) => e,
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                if let Err(e) = engine.warmup() {
                    let _ = ready_tx.send(Err(format!("{e:#}")));
                    return;
                }
                let _ = ready_tx.send(Ok(()));
                let mut waiters: Vec<(RequestId, Sender<Response>)> = Vec::new();
                loop {
                    // drain the mailbox without blocking while work exists
                    let busy = engine.n_live() > 0 || engine.n_queued() > 0;
                    let msg = if busy {
                        match rx.try_recv() {
                            Ok(m) => Some(m),
                            Err(std::sync::mpsc::TryRecvError::Empty) => None,
                            Err(std::sync::mpsc::TryRecvError::Disconnected) => break,
                        }
                    } else {
                        match rx.recv() {
                            Ok(m) => Some(m),
                            Err(_) => break,
                        }
                    };
                    match msg {
                        Some(Msg::Submit(req, resp_tx)) => {
                            waiters.push((req.id, resp_tx));
                            engine.submit(req);
                        }
                        Some(Msg::Report(tx)) => {
                            let _ = tx.send(engine.metrics.report());
                        }
                        Some(Msg::Shutdown) => break,
                        None => {}
                    }
                    if engine.n_live() > 0 || engine.n_queued() > 0 {
                        match engine.step() {
                            Ok(done) => {
                                for resp in done {
                                    if let Some(pos) =
                                        waiters.iter().position(|(id, _)| *id == resp.id)
                                    {
                                        let (_, tx) = waiters.swap_remove(pos);
                                        let _ = tx.send(resp);
                                    }
                                }
                            }
                            Err(e) => {
                                eprintln!("engine step error: {e:#}");
                                break;
                            }
                        }
                    }
                }
            })?;
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => return Err(anyhow::anyhow!(e)),
            Err(_) => return Err(anyhow::anyhow!("engine thread died during startup")),
        }
        Ok(ServerHandle { tx, join: Some(join), next_id: 1 })
    }

    /// Submit a prompt; returns a receiver for the final response.
    pub fn submit(
        &mut self,
        prompt: Vec<u16>,
        max_new: usize,
        params: SamplingParams,
    ) -> Receiver<Response> {
        let id = self.next_id;
        self.next_id += 1;
        let (tx, rx) = channel();
        let req = Request {
            id,
            prompt,
            max_new_tokens: max_new,
            params,
            stop_at_eos: false,
        };
        let _ = self.tx.send(Msg::Submit(req, tx));
        rx
    }

    pub fn metrics_report(&self) -> Option<String> {
        let (tx, rx) = channel();
        self.tx.send(Msg::Report(tx)).ok()?;
        rx.recv().ok()
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}
