//! Threaded serving front door.
//!
//! tokio is not in the offline vendor set — and one executor thread is
//! the natural shape for one PJRT CPU device — so the server is a
//! dedicated engine thread plus std::mpsc channels: clients submit
//! requests with a response channel and block (or poll) on it. This is
//! the same single-owner architecture a GPU-stream-bound executor uses.
//!
//! The loop is generic over [`EngineCore`], so the same front door
//! drives the XLA-backed [`Engine`] and the artifact-free
//! [`NativeEngine`] — `examples/serve_batch.rs` picks the backend with
//! a flag.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use anyhow::Result;

use crate::cache::CacheStats;
use crate::coordinator::engine::{Engine, EngineConfig};
use crate::coordinator::metrics::MetricsSnapshot;
use crate::coordinator::native::{NativeEngine, NativeEngineConfig};
use crate::coordinator::request::{Request, RequestId, Response, SamplingParams};
use crate::runtime::Runtime;
use crate::ssm::StepModel;

/// What the serving loop needs from an execution engine. `Engine`
/// (XLA) and `NativeEngine` (pure rust) both implement it; the boxed
/// core never leaves the engine thread, so non-`Send` engines (the
/// PJRT client) are fine.
pub trait EngineCore {
    fn submit(&mut self, req: Request);
    fn step(&mut self) -> Result<Vec<Response>>;
    fn n_queued(&self) -> usize;
    fn n_live(&self) -> usize;
    fn report(&self) -> String;
    /// Prefix-cache counters; `None` when the engine serves without a
    /// cache (the default for cores that never prefill, e.g. tests).
    fn cache_stats(&self) -> Option<CacheStats> {
        None
    }
    /// Admission-controlled submit: `Some(resp)` is an immediate typed
    /// rejection (bounded queue full) the server relays to the waiter
    /// without a tick. Cores without admission control accept
    /// unconditionally.
    fn try_submit(&mut self, req: Request) -> Option<Response> {
        self.submit(req);
        None
    }
    /// Cancel a queued or live request; `None` when unknown (already
    /// finished, or the core doesn't support cancellation).
    fn cancel(&mut self, _id: RequestId) -> Option<Response> {
        None
    }
    /// Typed metrics snapshot for the `/metrics` exporter; `None` for
    /// cores that only format a report string.
    fn metrics_snapshot(&self) -> Option<MetricsSnapshot> {
        None
    }
    /// Chrome trace-event JSON dump of the flight recorder; `None`
    /// when the core traces nothing (the default).
    fn dump_trace(&self) -> Option<String> {
        None
    }
}

impl EngineCore for Engine {
    fn submit(&mut self, req: Request) {
        Engine::submit(self, req)
    }
    fn step(&mut self) -> Result<Vec<Response>> {
        Engine::step(self)
    }
    fn n_queued(&self) -> usize {
        Engine::n_queued(self)
    }
    fn n_live(&self) -> usize {
        Engine::n_live(self)
    }
    fn report(&self) -> String {
        self.metrics.report()
    }
    fn cache_stats(&self) -> Option<CacheStats> {
        Engine::cache_stats(self)
    }
    fn metrics_snapshot(&self) -> Option<MetricsSnapshot> {
        Some(Engine::metrics_snapshot(self))
    }
}

impl EngineCore for NativeEngine {
    fn submit(&mut self, req: Request) {
        NativeEngine::submit(self, req)
    }
    fn step(&mut self) -> Result<Vec<Response>> {
        NativeEngine::step(self)
    }
    fn n_queued(&self) -> usize {
        NativeEngine::n_queued(self)
    }
    fn n_live(&self) -> usize {
        NativeEngine::n_live(self)
    }
    fn report(&self) -> String {
        self.metrics.report()
    }
    fn cache_stats(&self) -> Option<CacheStats> {
        NativeEngine::cache_stats(self)
    }
    fn try_submit(&mut self, req: Request) -> Option<Response> {
        NativeEngine::try_submit(self, req)
    }
    fn cancel(&mut self, id: RequestId) -> Option<Response> {
        NativeEngine::cancel(self, id)
    }
    fn metrics_snapshot(&self) -> Option<MetricsSnapshot> {
        Some(NativeEngine::metrics_snapshot(self))
    }
    fn dump_trace(&self) -> Option<String> {
        NativeEngine::dump_trace(self)
    }
}

enum Msg {
    Submit(Request, Sender<Response>),
    Cancel(RequestId),
    Report(Sender<String>),
    CacheStats(Sender<Option<CacheStats>>),
    MetricsSnapshot(Sender<Option<MetricsSnapshot>>),
    DumpTrace(Sender<Option<String>>),
    Shutdown,
}

pub struct ServerHandle {
    tx: Sender<Msg>,
    join: Option<JoinHandle<()>>,
    next_id: RequestId,
}

impl ServerHandle {
    /// Spawn an engine thread around any [`EngineCore`] factory. The
    /// factory runs *inside* the thread (the PJRT client is not Send).
    pub fn spawn_core<F>(make: F) -> Result<ServerHandle>
    where
        F: FnOnce() -> Result<Box<dyn EngineCore>> + Send + 'static,
    {
        let (tx, rx): (Sender<Msg>, Receiver<Msg>) = channel();
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let join = std::thread::Builder::new()
            .name("quamba-engine".into())
            .spawn(move || {
                let mut engine = match make() {
                    Ok(e) => e,
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                let _ = ready_tx.send(Ok(()));
                let mut waiters: Vec<(RequestId, Sender<Response>)> = Vec::new();
                loop {
                    // drain the mailbox without blocking while work exists
                    let busy = engine.n_live() > 0 || engine.n_queued() > 0;
                    let msg = if busy {
                        match rx.try_recv() {
                            Ok(m) => Some(m),
                            Err(std::sync::mpsc::TryRecvError::Empty) => None,
                            Err(std::sync::mpsc::TryRecvError::Disconnected) => break,
                        }
                    } else {
                        match rx.recv() {
                            Ok(m) => Some(m),
                            Err(_) => break,
                        }
                    };
                    match msg {
                        Some(Msg::Submit(req, resp_tx)) => {
                            let id = req.id;
                            // A rejected submit must answer synchronously:
                            // an idle engine may never step again, so a
                            // parked waiter would hang forever.
                            match engine.try_submit(req) {
                                Some(reject) => {
                                    let _ = resp_tx.send(reject);
                                }
                                None => waiters.push((id, resp_tx)),
                            }
                        }
                        Some(Msg::Cancel(id)) => {
                            if let Some(resp) = engine.cancel(id) {
                                if let Some(pos) =
                                    waiters.iter().position(|(wid, _)| *wid == resp.id)
                                {
                                    let (_, tx) = waiters.swap_remove(pos);
                                    let _ = tx.send(resp);
                                }
                            }
                        }
                        Some(Msg::Report(tx)) => {
                            let _ = tx.send(engine.report());
                        }
                        Some(Msg::CacheStats(tx)) => {
                            let _ = tx.send(engine.cache_stats());
                        }
                        Some(Msg::MetricsSnapshot(tx)) => {
                            let _ = tx.send(engine.metrics_snapshot());
                        }
                        Some(Msg::DumpTrace(tx)) => {
                            let _ = tx.send(engine.dump_trace());
                        }
                        Some(Msg::Shutdown) => break,
                        None => {}
                    }
                    if engine.n_live() > 0 || engine.n_queued() > 0 {
                        match engine.step() {
                            Ok(done) => {
                                for resp in done {
                                    if let Some(pos) =
                                        waiters.iter().position(|(id, _)| *id == resp.id)
                                    {
                                        let (_, tx) = waiters.swap_remove(pos);
                                        let _ = tx.send(resp);
                                    }
                                }
                            }
                            Err(e) => {
                                eprintln!("engine step error: {e:#}");
                                break;
                            }
                        }
                    }
                }
            })?;
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => return Err(anyhow::anyhow!(e)),
            Err(_) => return Err(anyhow::anyhow!("engine thread died during startup")),
        }
        Ok(ServerHandle { tx, join: Some(join), next_id: 1 })
    }

    /// Spawn the XLA-backed engine thread (artifact tree required).
    pub fn spawn(artifacts_root: std::path::PathBuf, cfg: EngineConfig) -> Result<ServerHandle> {
        Self::spawn_core(move || {
            let rt = Runtime::new(&artifacts_root)?;
            let mut engine = Engine::new(rt, cfg)?;
            engine.warmup()?;
            Ok(Box::new(engine) as Box<dyn EngineCore>)
        })
    }

    /// Spawn the artifact-free native engine thread around a
    /// [`StepModel`] (fp32 reference or W8A8 quantized). `Sync` lets
    /// the engine share the model across its decode worker threads.
    pub fn spawn_native(
        model: Box<dyn StepModel + Send + Sync>,
        cfg: NativeEngineConfig,
    ) -> Result<ServerHandle> {
        Self::spawn_core(move || Ok(Box::new(NativeEngine::new(model, cfg)) as Box<dyn EngineCore>))
    }

    /// Spawn the native engine with a speculative-decoding draft model
    /// alongside the target. `cfg.spec_tokens > 0` activates the
    /// draft/verify loop; the token streams stay bit-identical to
    /// [`spawn_native`](Self::spawn_native) by construction.
    pub fn spawn_native_with_draft(
        model: Box<dyn StepModel + Send + Sync>,
        draft: Box<dyn StepModel + Send + Sync>,
        cfg: NativeEngineConfig,
    ) -> Result<ServerHandle> {
        Self::spawn_core(move || {
            Ok(Box::new(NativeEngine::with_draft(model, draft, cfg)) as Box<dyn EngineCore>)
        })
    }

    /// Submit a prompt; returns a receiver for the final response.
    pub fn submit(
        &mut self,
        prompt: Vec<u16>,
        max_new: usize,
        params: SamplingParams,
    ) -> Receiver<Response> {
        self.submit_with_id(prompt, max_new, params).1
    }

    /// Like [`submit`](Self::submit) but also returns the assigned
    /// request id, so the caller can [`cancel`](Self::cancel) it later.
    pub fn submit_with_id(
        &mut self,
        prompt: Vec<u16>,
        max_new: usize,
        params: SamplingParams,
    ) -> (RequestId, Receiver<Response>) {
        let id = self.next_id;
        self.next_id += 1;
        let (tx, rx) = channel();
        let req = Request {
            id,
            prompt,
            max_new_tokens: max_new,
            params,
            stop_at_eos: false,
        };
        let _ = self.tx.send(Msg::Submit(req, tx));
        (id, rx)
    }

    /// Request cancellation of a queued or live request. Best-effort:
    /// if the request already finished (or the backend doesn't support
    /// cancellation) this is a no-op; otherwise the waiter receives a
    /// `Cancelled` response with any tokens generated so far.
    pub fn cancel(&self, id: RequestId) {
        let _ = self.tx.send(Msg::Cancel(id));
    }

    pub fn metrics_report(&self) -> Option<String> {
        let (tx, rx) = channel();
        self.tx.send(Msg::Report(tx)).ok()?;
        rx.recv().ok()
    }

    /// Prefix-cache counters from the engine thread (`None` when the
    /// engine runs without a cache).
    pub fn cache_stats(&self) -> Option<CacheStats> {
        let (tx, rx) = channel();
        self.tx.send(Msg::CacheStats(tx)).ok()?;
        rx.recv().ok().flatten()
    }

    /// Typed metrics snapshot from the engine thread (`None` when the
    /// core doesn't expose one, or the engine is gone).
    pub fn metrics_snapshot(&self) -> Option<MetricsSnapshot> {
        let (tx, rx) = channel();
        self.tx.send(Msg::MetricsSnapshot(tx)).ok()?;
        rx.recv().ok().flatten()
    }

    /// Chrome trace-event JSON from the engine thread's flight
    /// recorder (`None` when tracing is off or the engine is gone).
    pub fn dump_trace(&self) -> Option<String> {
        let (tx, rx) = channel();
        self.tx.send(Msg::DumpTrace(tx)).ok()?;
        rx.recv().ok().flatten()
    }

    /// A `Send` fetch closure for [`crate::obs::MetricsExporter`]: each
    /// scrape round-trips the mailbox for a fresh snapshot. The clone
    /// of the sender keeps the engine thread alive no longer than the
    /// exporter — a dropped engine answers `None` (scrape → 503).
    pub fn snapshot_fetch(&self) -> crate::obs::SnapshotFetch {
        let tx = self.tx.clone();
        Box::new(move || {
            let (stx, srx) = channel();
            tx.send(Msg::MetricsSnapshot(stx)).ok()?;
            srx.recv().ok().flatten()
        })
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}
