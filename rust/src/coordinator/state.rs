//! Per-request model state pools — the memory story of paper Fig 1(c).
//!
//! * [`SsmStatePool`]: each request owns a *constant-size* slab
//!   (conv window + recurrent state), independent of how many tokens it
//!   has consumed. Gather/scatter pack request slabs into the batched
//!   (L, B, ...) tensors the decode graphs expect. Pools serving a
//!   quantized-conv model ([`Self::with_quantized_conv`]) store the
//!   conv window as i8 codes — 1 byte/entry instead of 4.
//! * [`KvCachePool`]: the Transformer comparator — each request's slab
//!   grows with its context; a capacity watermark drives backpressure.

use crate::config::{TierInfo, TransformerTierInfo};
use crate::ssm::{MambaState, MambaTier};
use crate::tensor::Tensor;

/// Constant-size per-request SSM state slab. Exactly one of `conv`
/// (f32 values) / `conv_q` (i8 codes, quantized-conv pools) is
/// populated; the other stays empty.
#[derive(Clone)]
pub struct SsmSlab {
    /// (L, W-1, d_inner) flattened, f32 pools
    pub conv: Vec<f32>,
    /// (L, W-1, d_inner) flattened i8 codes, quantized-conv pools
    pub conv_q: Vec<i8>,
    /// (L, d_inner, N) flattened
    pub ssm: Vec<f32>,
}

impl SsmSlab {
    /// Payload bytes of this slab — the quantity the prefix cache
    /// budgets. Constant in context length (the SSM selling point).
    pub fn bytes(&self) -> usize {
        4 * self.conv.len() + self.conv_q.len() + 4 * self.ssm.len()
    }
}

pub struct SsmStatePool {
    pub n_layer: usize,
    pub d_inner: usize,
    pub conv_per_layer: usize, // (W-1) * d_inner
    pub ssm_per_layer: usize,  // d_inner * N
    /// conv windows held as i8 codes (W8A8 native serving)
    pub quantized_conv: bool,
    slots: Vec<Option<SsmSlab>>,
    free: Vec<usize>,
}

impl SsmStatePool {
    pub fn new(tier: &TierInfo, capacity: usize) -> Self {
        Self::with_dims(tier.n_layer, tier.d_inner, tier.d_conv, tier.d_state, capacity)
    }

    /// Dimension-level constructor — lets the native backend build a
    /// pool straight from a [`crate::ssm::MambaTier`] without an
    /// artifact-manifest `TierInfo`.
    pub fn with_dims(
        n_layer: usize,
        d_inner: usize,
        d_conv: usize,
        d_state: usize,
        capacity: usize,
    ) -> Self {
        SsmStatePool {
            n_layer,
            d_inner,
            conv_per_layer: (d_conv - 1) * d_inner,
            ssm_per_layer: d_inner * d_state,
            quantized_conv: false,
            slots: (0..capacity).map(|_| None).collect(),
            free: (0..capacity).rev().collect(),
        }
    }

    /// Switch the pool to i8 conv-window slabs (quarter the conv
    /// bytes); use with [`crate::ssm::StepModel::quantized_conv_state`]
    /// models and the `*_raw_q` gather/scatter pair.
    pub fn with_quantized_conv(mut self) -> Self {
        assert_eq!(self.in_use(), 0, "cannot change slab dtype with live slots");
        self.quantized_conv = true;
        self
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn in_use(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    /// Bytes a single request's state occupies — CONSTANT in context
    /// length (the SSM selling point). Quantized-conv pools spend one
    /// byte per conv entry instead of four.
    pub fn bytes_per_request(&self) -> usize {
        let conv_bytes = if self.quantized_conv { 1 } else { 4 };
        self.n_layer * (conv_bytes * self.conv_per_layer + 4 * self.ssm_per_layer)
    }

    pub fn alloc(&mut self) -> Option<usize> {
        let slot = self.free.pop()?;
        let (conv, conv_q) = if self.quantized_conv {
            (Vec::new(), vec![0i8; self.n_layer * self.conv_per_layer])
        } else {
            (vec![0.0; self.n_layer * self.conv_per_layer], Vec::new())
        };
        self.slots[slot] = Some(SsmSlab {
            conv,
            conv_q,
            ssm: vec![0.0; self.n_layer * self.ssm_per_layer],
        });
        Some(slot)
    }

    pub fn release(&mut self, slot: usize) {
        assert!(self.slots[slot].is_some(), "double free of slot {slot}");
        self.slots[slot] = None;
        self.free.push(slot);
    }

    /// Slot-leak audit (ISSUE 7): every slot is either occupied or on
    /// the free list, exactly once. `in_use()` is *derived* from the
    /// free-list length, so a leak shows up here as an occupied slot
    /// the free list also claims (or a vacant one it doesn't) — the
    /// chaos suite calls this after every engine tick.
    pub fn check_conservation(&self) -> Result<(), String> {
        let occupied = self.slots.iter().filter(|s| s.is_some()).count();
        if occupied + self.free.len() != self.slots.len() {
            return Err(format!(
                "slot conservation broken: {occupied} occupied + {} free != {} capacity",
                self.free.len(),
                self.slots.len()
            ));
        }
        let mut on_free_list = vec![false; self.slots.len()];
        for &f in &self.free {
            if f >= self.slots.len() {
                return Err(format!("free list holds out-of-range slot {f}"));
            }
            if self.slots[f].is_some() {
                return Err(format!("slot {f} is on the free list but occupied"));
            }
            if on_free_list[f] {
                return Err(format!("slot {f} appears twice on the free list"));
            }
            on_free_list[f] = true;
        }
        Ok(())
    }

    pub fn write(&mut self, slot: usize, slab: SsmSlab) {
        assert!(
            self.slots[slot].is_some(),
            "write into unallocated slot {slot} (released or never alloc'd)"
        );
        if self.quantized_conv {
            assert_eq!(slab.conv_q.len(), self.n_layer * self.conv_per_layer);
            assert!(slab.conv.is_empty(), "quantized-conv pool got an f32 conv slab");
        } else {
            assert_eq!(slab.conv.len(), self.n_layer * self.conv_per_layer);
            assert!(slab.conv_q.is_empty(), "f32 pool got a quantized conv slab");
        }
        assert_eq!(slab.ssm.len(), self.n_layer * self.ssm_per_layer);
        self.slots[slot] = Some(slab);
    }

    pub fn get(&self, slot: usize) -> &SsmSlab {
        self.slots[slot].as_ref().expect("slot not allocated")
    }

    /// O(1)-in-context-length clone of a live slot's state — the
    /// prefix-cache admission primitive. Panics on a released / stale
    /// slot (a snapshot of freed state would cache garbage).
    pub fn snapshot(&self, slot: usize) -> SsmSlab {
        self.slots[slot]
            .as_ref()
            .unwrap_or_else(|| panic!("snapshot of unallocated slot {slot}"))
            .clone()
    }

    /// Clone a (cached) slab into a live slot — the prefix-cache hit
    /// primitive, replacing the gather/scatter round-trip. Validates
    /// the slab against the pool's dtype + dims and panics on a
    /// released / stale slot, so a double-released or recycled slot
    /// cannot silently resurrect with cached state.
    pub fn restore(&mut self, slot: usize, slab: &SsmSlab) {
        assert!(
            self.slots[slot].is_some(),
            "restore into unallocated slot {slot} (released or never alloc'd)"
        );
        self.write(slot, slab.clone());
    }

    /// Pack `slots` into raw batched (L, B, ...) f32 buffers for a
    /// decode graph of batch `b` (slots.len() ≤ b; missing slots pad
    /// with zeros — those lanes' outputs are discarded by scatter).
    /// Raw form feeds `runtime::lit_from_f32` on the hot path.
    pub fn gather_raw(&self, slots: &[usize], b: usize) -> (Vec<f32>, Vec<f32>) {
        assert!(!self.quantized_conv, "quantized-conv pool: use gather_raw_q");
        let (l, cpl, spl) = (self.n_layer, self.conv_per_layer, self.ssm_per_layer);
        let mut conv = vec![0.0f32; l * b * cpl];
        let mut ssm = vec![0.0f32; l * b * spl];
        for (bi, &slot) in slots.iter().enumerate() {
            let slab = self.get(slot);
            for li in 0..l {
                conv[(li * b + bi) * cpl..(li * b + bi + 1) * cpl]
                    .copy_from_slice(&slab.conv[li * cpl..(li + 1) * cpl]);
                ssm[(li * b + bi) * spl..(li * b + bi + 1) * spl]
                    .copy_from_slice(&slab.ssm[li * spl..(li + 1) * spl]);
            }
        }
        (conv, ssm)
    }

    /// Tensor-typed convenience wrapper over [`Self::gather_raw`].
    pub fn gather(&self, slots: &[usize], b: usize) -> (Tensor, Tensor) {
        let (conv, ssm) = self.gather_raw(slots, b);
        let (l, cpl, spl) = (self.n_layer, self.conv_per_layer, self.ssm_per_layer);
        let di = self.d_inner;
        let conv_t = Tensor::from_f32(&[l, b, cpl / di, di], &conv);
        let ssm_t = Tensor::from_f32(&[l, b, di, spl / di], &ssm);
        (conv_t, ssm_t)
    }

    /// Scatter raw batched output states back into request slots.
    pub fn scatter_raw(&mut self, slots: &[usize], b: usize, cf: &[f32], sf: &[f32]) {
        assert!(!self.quantized_conv, "quantized-conv pool: use scatter_raw_q");
        let l = self.n_layer;
        let cpl = self.conv_per_layer;
        let spl = self.ssm_per_layer;
        debug_assert_eq!(cf.len(), l * b * cpl);
        debug_assert_eq!(sf.len(), l * b * spl);
        for (bi, &slot) in slots.iter().enumerate() {
            let mut slab = SsmSlab {
                conv: vec![0.0; l * cpl],
                conv_q: Vec::new(),
                ssm: vec![0.0; l * spl],
            };
            for li in 0..l {
                slab.conv[li * cpl..(li + 1) * cpl]
                    .copy_from_slice(&cf[(li * b + bi) * cpl..(li * b + bi + 1) * cpl]);
                slab.ssm[li * spl..(li + 1) * spl]
                    .copy_from_slice(&sf[(li * b + bi) * spl..(li * b + bi + 1) * spl]);
            }
            self.write(slot, slab);
        }
    }

    /// Pack `slots` into raw batched (L, B, ...) buffers with the conv
    /// window as i8 codes — the quantized-conv twin of
    /// [`Self::gather_raw`], feeding `MambaState::from_raw_q`.
    pub fn gather_raw_q(&self, slots: &[usize], b: usize) -> (Vec<i8>, Vec<f32>) {
        assert!(self.quantized_conv, "f32 pool: use gather_raw");
        let (l, cpl, spl) = (self.n_layer, self.conv_per_layer, self.ssm_per_layer);
        let mut conv_q = vec![0i8; l * b * cpl];
        let mut ssm = vec![0.0f32; l * b * spl];
        for (bi, &slot) in slots.iter().enumerate() {
            let slab = self.get(slot);
            for li in 0..l {
                conv_q[(li * b + bi) * cpl..(li * b + bi + 1) * cpl]
                    .copy_from_slice(&slab.conv_q[li * cpl..(li + 1) * cpl]);
                ssm[(li * b + bi) * spl..(li * b + bi + 1) * spl]
                    .copy_from_slice(&slab.ssm[li * spl..(li + 1) * spl]);
            }
        }
        (conv_q, ssm)
    }

    /// Scatter i8-conv batched output states back into request slots —
    /// the quantized-conv twin of [`Self::scatter_raw`].
    pub fn scatter_raw_q(&mut self, slots: &[usize], b: usize, cq: &[i8], sf: &[f32]) {
        assert!(self.quantized_conv, "f32 pool: use scatter_raw");
        let l = self.n_layer;
        let cpl = self.conv_per_layer;
        let spl = self.ssm_per_layer;
        debug_assert_eq!(cq.len(), l * b * cpl);
        debug_assert_eq!(sf.len(), l * b * spl);
        for (bi, &slot) in slots.iter().enumerate() {
            let mut slab = SsmSlab {
                conv: Vec::new(),
                conv_q: vec![0i8; l * cpl],
                ssm: vec![0.0; l * spl],
            };
            for li in 0..l {
                slab.conv_q[li * cpl..(li + 1) * cpl]
                    .copy_from_slice(&cq[(li * b + bi) * cpl..(li * b + bi + 1) * cpl]);
                slab.ssm[li * spl..(li + 1) * spl]
                    .copy_from_slice(&sf[(li * b + bi) * spl..(li * b + bi + 1) * spl]);
            }
            self.write(slot, slab);
        }
    }

    /// Tensor-typed convenience wrapper over [`Self::scatter_raw`].
    pub fn scatter(&mut self, slots: &[usize], conv: &Tensor, ssm: &Tensor) {
        let b = conv.shape[1];
        self.scatter_raw(slots, b, &conv.to_f32(), &ssm.to_f32());
    }

    /// Pack `slots` into a batched [`MambaState`] of `b` lanes
    /// (missing lanes zero-padded; their outputs are dropped by
    /// [`Self::scatter_state`]) — the gather side of one decode round
    /// or one (B, T) prefill-chunk batch of the unified scheduler.
    /// Dispatches on the pool's conv dtype so callers stop hand-rolling
    /// the `gather_raw{,_q}` → `MambaState::from_raw{,_q}` dance.
    pub fn gather_state(&self, tier: &MambaTier, slots: &[usize], b: usize) -> MambaState {
        if self.quantized_conv {
            let (conv_q, ssm) = self.gather_raw_q(slots, b);
            MambaState::from_raw_q(tier, b, conv_q, ssm)
        } else {
            let (conv, ssm) = self.gather_raw(slots, b);
            MambaState::from_raw(tier, b, conv, ssm)
        }
    }

    /// Scatter a batched [`MambaState`] back into request slots — the
    /// inverse of [`Self::gather_state`] (consumes the state; padded
    /// lanes beyond `slots.len()` are discarded).
    pub fn scatter_state(&mut self, slots: &[usize], state: MambaState) {
        let b = state.b;
        if state.is_quantized_conv() {
            let (conv_q, ssm) = state.into_raw_q();
            self.scatter_raw_q(slots, b, &conv_q, &ssm);
        } else {
            let (conv, ssm) = state.into_raw();
            self.scatter_raw(slots, b, &conv, &ssm);
        }
    }
}

/// KV-cache pool for the Transformer baseline: bytes grow linearly
/// with each request's context length.
pub struct KvCachePool {
    pub n_layer: usize,
    pub n_head: usize,
    pub d_head: usize,
    pub max_ctx: usize,
    /// context length per live request slot
    lengths: Vec<Option<usize>>,
    /// capacity watermark in bytes (backpressure trigger)
    pub byte_budget: usize,
}

impl KvCachePool {
    pub fn new(tier: &TransformerTierInfo, capacity: usize, byte_budget: usize) -> Self {
        KvCachePool {
            n_layer: tier.n_layer,
            n_head: tier.n_head,
            d_head: tier.d_model / tier.n_head,
            max_ctx: tier.max_ctx,
            lengths: vec![None; capacity],
            byte_budget,
        }
    }

    /// Bytes one request at context length `ctx` occupies (K + V, f32).
    pub fn bytes_per_request(&self, ctx: usize) -> usize {
        2 * 4 * self.n_layer * self.n_head * self.d_head * ctx
    }

    pub fn total_bytes(&self) -> usize {
        self.lengths
            .iter()
            .flatten()
            .map(|&c| self.bytes_per_request(c))
            .sum()
    }

    /// Admit a request with prompt length `ctx`; None = backpressure.
    pub fn alloc(&mut self, ctx: usize) -> Option<usize> {
        if self.total_bytes() + self.bytes_per_request(ctx) > self.byte_budget {
            return None;
        }
        let slot = self.lengths.iter().position(|l| l.is_none())?;
        self.lengths[slot] = Some(ctx);
        Some(slot)
    }

    pub fn grow(&mut self, slot: usize, by: usize) {
        if let Some(l) = self.lengths[slot].as_mut() {
            *l = (*l + by).min(self.max_ctx);
        }
    }

    pub fn release(&mut self, slot: usize) {
        self.lengths[slot] = None;
    }

    pub fn in_use(&self) -> usize {
        self.lengths.iter().flatten().count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tier() -> TierInfo {
        TierInfo {
            name: "t".into(),
            paper_name: "T".into(),
            d_model: 8,
            n_layer: 2,
            d_state: 4,
            d_conv: 4,
            d_inner: 16,
            dt_rank: 1,
            vocab: 256,
            n_params: 0,
        }
    }

    #[test]
    fn alloc_release_cycle() {
        let mut p = SsmStatePool::new(&tier(), 3);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        let c = p.alloc().unwrap();
        assert!(p.alloc().is_none());
        assert_eq!(p.in_use(), 3);
        p.release(b);
        assert_eq!(p.in_use(), 2);
        let b2 = p.alloc().unwrap();
        assert_eq!(b2, b);
        let _ = (a, c);
    }

    #[test]
    fn conservation_holds_across_alloc_release_churn() {
        // the audit the chaos suite runs every tick: occupied + free
        // always partitions the slot set, whatever the churn pattern
        let mut p = SsmStatePool::new(&tier(), 4);
        p.check_conservation().unwrap();
        let mut held: Vec<usize> = Vec::new();
        for round in 0..50u64 {
            // deterministic mixed pattern: alloc on most rounds,
            // release the oldest on every third
            if round % 3 == 2 {
                if !held.is_empty() {
                    p.release(held.remove(0));
                }
            } else if let Some(s) = p.alloc() {
                held.push(s);
            }
            p.check_conservation()
                .unwrap_or_else(|e| panic!("round {round}: {e}"));
            assert_eq!(p.in_use(), held.len());
        }
        for s in held {
            p.release(s);
            p.check_conservation().unwrap();
        }
        assert_eq!(p.in_use(), 0);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let t = tier();
        let mut p = SsmStatePool::new(&t, 4);
        let s0 = p.alloc().unwrap();
        let s1 = p.alloc().unwrap();
        // write recognizable values
        let mut slab = p.get(s0).clone();
        slab.conv.iter_mut().enumerate().for_each(|(i, v)| *v = i as f32);
        slab.ssm.iter_mut().enumerate().for_each(|(i, v)| *v = -(i as f32));
        p.write(s0, slab.clone());
        let (conv, ssm) = p.gather(&[s0, s1], 4);
        assert_eq!(conv.shape, vec![2, 4, 3, 16]);
        assert_eq!(ssm.shape, vec![2, 4, 16, 4]);
        // scatter back into fresh slots and compare
        let mut p2 = SsmStatePool::new(&t, 4);
        let d0 = p2.alloc().unwrap();
        let d1 = p2.alloc().unwrap();
        p2.scatter(&[d0, d1], &conv, &ssm);
        assert_eq!(p2.get(d0).conv, slab.conv);
        assert_eq!(p2.get(d0).ssm, slab.ssm);
        assert!(p2.get(d1).conv.iter().all(|v| *v == 0.0));
    }

    #[test]
    fn quantized_pool_roundtrip_and_bytes() {
        let t = tier();
        let mut p = SsmStatePool::new(&t, 4).with_quantized_conv();
        let f32_pool = SsmStatePool::new(&t, 4);
        // conv entries drop from 4 bytes to 1
        let cpl_bytes = t.n_layer * (t.d_conv - 1) * t.d_inner;
        assert_eq!(f32_pool.bytes_per_request() - p.bytes_per_request(), 3 * cpl_bytes);
        let s0 = p.alloc().unwrap();
        let s1 = p.alloc().unwrap();
        let mut slab = p.get(s0).clone();
        slab.conv_q.iter_mut().enumerate().for_each(|(i, v)| *v = (i % 100) as i8 - 50);
        slab.ssm.iter_mut().enumerate().for_each(|(i, v)| *v = i as f32);
        p.write(s0, slab.clone());
        let (cq, sf) = p.gather_raw_q(&[s0, s1], 3);
        let mut p2 = SsmStatePool::new(&t, 4).with_quantized_conv();
        let d0 = p2.alloc().unwrap();
        let d1 = p2.alloc().unwrap();
        p2.scatter_raw_q(&[d0, d1], 3, &cq, &sf);
        assert_eq!(p2.get(d0).conv_q, slab.conv_q);
        assert_eq!(p2.get(d0).ssm, slab.ssm);
        assert!(p2.get(d1).conv_q.iter().all(|v| *v == 0));
    }

    #[test]
    fn gather_scatter_state_roundtrip_both_dtypes() {
        let t = tier();
        let mt = MambaTier {
            name: t.name.clone(),
            d_model: t.d_model,
            n_layer: t.n_layer,
            d_state: t.d_state,
            d_conv: t.d_conv,
            d_inner: t.d_inner,
            dt_rank: t.dt_rank,
            vocab: t.vocab,
        };
        // f32 pool
        let mut p = SsmStatePool::new(&t, 4);
        let s0 = p.alloc().unwrap();
        let s1 = p.alloc().unwrap();
        let mut slab = p.get(s0).clone();
        slab.conv.iter_mut().enumerate().for_each(|(i, v)| *v = i as f32 + 0.25);
        slab.ssm.iter_mut().enumerate().for_each(|(i, v)| *v = -(i as f32));
        p.write(s0, slab.clone());
        let st = p.gather_state(&mt, &[s0, s1], 3);
        assert_eq!(st.b, 3);
        assert!(!st.is_quantized_conv());
        p.scatter_state(&[s1, s0], st); // swap on the way back
        assert_eq!(p.get(s1).conv, slab.conv);
        assert_eq!(p.get(s1).ssm, slab.ssm);
        // quantized-conv pool
        let mut q = SsmStatePool::new(&t, 4).with_quantized_conv();
        let q0 = q.alloc().unwrap();
        let mut qs = q.get(q0).clone();
        qs.conv_q.iter_mut().enumerate().for_each(|(i, v)| *v = (i % 90) as i8 - 45);
        q.write(q0, qs.clone());
        let st = q.gather_state(&mt, &[q0], 2);
        assert!(st.is_quantized_conv());
        q.scatter_state(&[q0], st);
        assert_eq!(q.get(q0).conv_q, qs.conv_q);
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let t = tier();
        let mut p = SsmStatePool::new(&t, 2);
        let src = p.alloc().unwrap();
        let dst = p.alloc().unwrap();
        let mut slab = p.get(src).clone();
        slab.conv.iter_mut().enumerate().for_each(|(i, v)| *v = i as f32 + 0.5);
        slab.ssm.iter_mut().enumerate().for_each(|(i, v)| *v = -(i as f32));
        p.write(src, slab);
        let snap = p.snapshot(src);
        assert_eq!(snap.bytes(), p.bytes_per_request());
        p.restore(dst, &snap);
        assert_eq!(p.get(dst).conv, p.get(src).conv);
        assert_eq!(p.get(dst).ssm, p.get(src).ssm);
        // restoring does not alias: mutating dst leaves src intact
        let mut d = p.get(dst).clone();
        d.conv[0] = 1e9;
        p.write(dst, d);
        assert_ne!(p.get(src).conv[0], 1e9);
    }

    #[test]
    #[should_panic(expected = "unallocated slot")]
    fn restore_into_released_slot_panics() {
        let t = tier();
        let mut p = SsmStatePool::new(&t, 2);
        let a = p.alloc().unwrap();
        let snap = p.snapshot(a);
        p.release(a);
        p.restore(a, &snap); // stale slot — must panic, not resurrect
    }

    #[test]
    #[should_panic(expected = "snapshot of unallocated slot")]
    fn snapshot_of_free_slot_panics() {
        let t = tier();
        let p = SsmStatePool::new(&t, 1);
        let _ = p.snapshot(0);
    }

    #[test]
    fn ssm_state_constant_kv_grows() {
        let t = tier();
        let p = SsmStatePool::new(&t, 1);
        let b0 = p.bytes_per_request();
        // context length does not appear anywhere in the SSM slab
        assert_eq!(b0, 4 * 2 * (3 * 16 + 16 * 4));
        let tt = TransformerTierInfo {
            name: "p".into(),
            paper_name: "P".into(),
            d_model: 16,
            n_layer: 2,
            n_head: 2,
            max_ctx: 128,
            vocab: 256,
            n_params: 0,
        };
        let kv = KvCachePool::new(&tt, 4, usize::MAX);
        assert!(kv.bytes_per_request(64) == 2 * kv.bytes_per_request(32));
    }

    #[test]
    fn kv_backpressure() {
        let tt = TransformerTierInfo {
            name: "p".into(),
            paper_name: "P".into(),
            d_model: 16,
            n_layer: 1,
            n_head: 2,
            max_ctx: 128,
            vocab: 256,
            n_params: 0,
        };
        let per32 = 2 * 4 * 1 * 2 * 8 * 32;
        let mut kv = KvCachePool::new(&tt, 8, per32 * 2);
        assert!(kv.alloc(32).is_some());
        assert!(kv.alloc(32).is_some());
        assert!(kv.alloc(32).is_none(), "watermark must reject the third");
        kv.release(0);
        assert!(kv.alloc(32).is_some());
    }
}
