//! Token sampling: greedy / temperature / top-k over a logits row.
//!
//! Two consumption styles share [`sample_row`]:
//! * [`Sampler`] — one engine-owned RNG stream (the Transformer
//!   baseline and the XLA engine, whose scheduling never reorders
//!   sampling relative to a fixed workload);
//! * a **per-request** `Pcg32` carried in
//!   [`crate::coordinator::request::LiveRequest::rng`] (the native
//!   engine): draws depend only on how many tokens that request has
//!   sampled, so chunked prefill / cache hits / scheduler interleaving
//!   can never change a sampled token.

use crate::coordinator::request::SamplingParams;
use crate::util::rng::Pcg32;

/// Sample a token from one logits row (`vocab` live entries) using the
/// caller's RNG stream.
///
/// **Greedy tie-break contract (ISSUE 10):** at `temperature <= 0.0`
/// this returns [`argmax`], which resolves exact float ties toward the
/// **lowest index**. Speculative decoding leans on this being a total,
/// deterministic function of the row: the draft's proposal and the
/// target's verification both call the same argmax, so a duplicated
/// maximum can never make acceptance depend on evaluation order.
/// Greedy sampling consumes **no** RNG draws; each temperature sample
/// consumes exactly one `weighted` draw — the accounting that lets the
/// verify path replay a lane's stream bit-exactly.
pub fn sample_row(rng: &mut Pcg32, logits: &[f32], vocab: usize, p: &SamplingParams) -> u16 {
    let row = &logits[..vocab.min(logits.len())];
    if p.temperature <= 0.0 {
        return argmax(row) as u16;
    }
    // temperature softmax over (optionally top-k) candidates
    let mut idx: Vec<usize> = (0..row.len()).collect();
    if p.top_k > 0 && p.top_k < row.len() {
        idx.sort_by(|&a, &b| row[b].partial_cmp(&row[a]).unwrap());
        idx.truncate(p.top_k);
    }
    let m = idx.iter().map(|&i| row[i]).fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f32> = idx
        .iter()
        .map(|&i| ((row[i] - m) / p.temperature).exp())
        .collect();
    idx[rng.weighted(&weights)] as u16
}

pub struct Sampler {
    rng: Pcg32,
}

impl Sampler {
    pub fn new(seed: u64) -> Self {
        Sampler { rng: Pcg32::new(seed) }
    }

    /// Sample a token from one logits row (`vocab` live entries).
    pub fn sample(&mut self, logits: &[f32], vocab: usize, p: &SamplingParams) -> u16 {
        sample_row(&mut self.rng, logits, vocab, p)
    }
}

/// Index of the row maximum; exact ties resolve to the **lowest**
/// index (strict `>` comparison). This tie-break is load-bearing for
/// speculative decoding's draft/target agreement — see [`sample_row`].
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    for i in 1..row.len() {
        if row[i] > row[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let mut s = Sampler::new(0);
        let logits = vec![0.0, 5.0, -1.0, 4.9];
        let p = SamplingParams::default();
        assert_eq!(s.sample(&logits, 4, &p), 1);
    }

    #[test]
    fn greedy_ties_break_to_lowest_index() {
        // duplicated maxima: strict `>` keeps the first occurrence,
        // wherever the duplicates sit — the speculative-decoding
        // acceptance check depends on this exact contract
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[4.0, 4.0, 4.0]), 0);
        assert_eq!(argmax(&[-1.0, 0.5, -1.0, 0.5, 0.5]), 1);
        // all-equal rows (the BOS-padded cold start) pick index 0
        assert_eq!(argmax(&[0.0; 8]), 0);
        // and sample_row at temperature 0 routes through argmax
        // without consuming any RNG draws
        let mut rng = Pcg32::new(7);
        let before = rng.clone().next_u32();
        let p = SamplingParams::default();
        assert_eq!(sample_row(&mut rng, &[2.0, 9.0, 9.0, 1.0], 4, &p), 1);
        assert_eq!(rng.next_u32(), before, "greedy must not advance the stream");
    }

    #[test]
    fn top_k_restricts_support() {
        let mut s = Sampler::new(1);
        let logits = vec![10.0, 9.0, -50.0, -50.0];
        let p = SamplingParams { temperature: 1.0, top_k: 2, ..Default::default() };
        for _ in 0..100 {
            let t = s.sample(&logits, 4, &p);
            assert!(t == 0 || t == 1, "sampled outside top-k: {t}");
        }
    }

    #[test]
    fn temperature_spreads_mass() {
        // with a huge temperature, both candidates should appear
        let mut s = Sampler::new(2);
        let logits = vec![1.0, 0.9];
        let p = SamplingParams { temperature: 50.0, ..Default::default() };
        let mut seen = [false; 2];
        for _ in 0..200 {
            seen[s.sample(&logits, 2, &p) as usize] = true;
        }
        assert!(seen[0] && seen[1]);
    }
}
