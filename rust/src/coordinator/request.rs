//! Request / response types and per-request lifecycle bookkeeping.

use std::time::Instant;

pub type RequestId = u64;

#[derive(Debug, Clone)]
pub struct SamplingParams {
    /// 0.0 = greedy
    pub temperature: f32,
    /// 0 = full vocab
    pub top_k: usize,
    pub seed: u64,
    /// opt this request out of the prefix cache: no probe on
    /// admission, no snapshots inserted (privacy-sensitive prompts /
    /// cache-pollution control). Tokens are identical either way — the
    /// cache only moves TTFT — so this is purely a policy knob.
    pub no_cache: bool,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams { temperature: 0.0, top_k: 0, seed: 0, no_cache: false }
    }
}

#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<u16>,
    pub max_new_tokens: usize,
    pub params: SamplingParams,
    /// stop at EOS (token 2)
    pub stop_at_eos: bool,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    Length,
    Eos,
    Cancelled,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: RequestId,
    pub tokens: Vec<u16>,
    pub finish: FinishReason,
    pub ttft_ms: f64,
    /// mean time-per-output-token over the decode phase
    pub tpot_ms: f64,
    /// time to last token (prefill + decode)
    pub ttlt_ms: f64,
}

/// Engine-internal per-request state.
pub struct LiveRequest {
    pub req: Request,
    pub generated: Vec<u16>,
    pub state_slot: usize,
    pub submitted: Instant,
    pub prefill_done: Option<Instant>,
    pub last_token: Option<Instant>,
    pub decode_ms: Vec<f64>,
}

impl LiveRequest {
    pub fn new(req: Request, state_slot: usize) -> Self {
        LiveRequest {
            req,
            generated: Vec::new(),
            state_slot,
            submitted: Instant::now(),
            prefill_done: None,
            last_token: None,
            decode_ms: Vec::new(),
        }
    }

    pub fn next_input_token(&self) -> u16 {
        *self
            .generated
            .last()
            .unwrap_or_else(|| self.req.prompt.last().expect("empty prompt"))
    }

    pub fn done(&self) -> bool {
        self.generated.len() >= self.req.max_new_tokens
            || (self.req.stop_at_eos && self.generated.last() == Some(&crate::data::EOS))
    }

    pub fn finish_reason(&self) -> FinishReason {
        if self.req.stop_at_eos && self.generated.last() == Some(&crate::data::EOS) {
            FinishReason::Eos
        } else {
            FinishReason::Length
        }
    }

    pub fn into_response(self) -> Response {
        let now = Instant::now();
        let ttft = self
            .prefill_done
            .map(|t| (t - self.submitted).as_secs_f64() * 1e3)
            .unwrap_or(f64::NAN);
        let tpot = if self.decode_ms.is_empty() {
            f64::NAN
        } else {
            self.decode_ms.iter().sum::<f64>() / self.decode_ms.len() as f64
        };
        let finish = self.finish_reason();
        Response {
            id: self.req.id,
            tokens: self.generated,
            finish,
            ttft_ms: ttft,
            tpot_ms: tpot,
            ttlt_ms: (now - self.submitted).as_secs_f64() * 1e3,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(max_new: usize) -> Request {
        Request {
            id: 1,
            prompt: vec![1, 5, 9],
            max_new_tokens: max_new,
            params: SamplingParams::default(),
            stop_at_eos: true,
        }
    }

    #[test]
    fn lifecycle_done_by_length() {
        let mut lr = LiveRequest::new(req(2), 0);
        assert!(!lr.done());
        assert_eq!(lr.next_input_token(), 9);
        lr.generated.push(7);
        assert_eq!(lr.next_input_token(), 7);
        assert!(!lr.done());
        lr.generated.push(8);
        assert!(lr.done());
        assert_eq!(lr.finish_reason(), FinishReason::Length);
    }

    #[test]
    fn lifecycle_done_by_eos() {
        let mut lr = LiveRequest::new(req(10), 0);
        lr.generated.push(crate::data::EOS);
        assert!(lr.done());
        assert_eq!(lr.finish_reason(), FinishReason::Eos);
    }
}
