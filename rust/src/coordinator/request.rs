//! Request / response types and per-request lifecycle bookkeeping.
//!
//! Since the unified chunked-prefill scheduler (ISSUE 5), a live
//! request moves through an explicit [`Phase`]: admitted requests
//! start `Prefilling { next }` (the scheduler advances their prompt in
//! chunks across ticks) and switch to `Decoding` once the first token
//! is sampled. Each request also owns its **own** sampler RNG stream
//! ([`LiveRequest::rng`], seeded from the engine sampler seed, the
//! request id and `SamplingParams::seed`): temperature draws depend
//! only on how many tokens *this* request has sampled, never on how
//! the scheduler interleaved it with other requests — the property
//! that keeps chunked, warm (cache-hit) and cold paths token-identical
//! under sampling, not just greedy decode.
//!
//! **Clock discipline (ISSUE 9):** every timestamp in this module is a
//! plain `f64` of clock-relative milliseconds handed in by the owning
//! engine (wall ms from its `WallAnchor` under `Clock::Wall`,
//! deterministic tick-derived ms under `Clock::Manual`). No type here
//! reads raw time — that is what keeps responses, traces and metrics
//! snapshots bit-reproducible under the manual clock, and what the
//! auditor's `clock-discipline` rule enforces.

use crate::util::rng::Pcg32;

pub type RequestId = u64;

#[derive(Debug, Clone)]
pub struct SamplingParams {
    /// 0.0 = greedy
    pub temperature: f32,
    /// 0 = full vocab
    pub top_k: usize,
    pub seed: u64,
    /// opt this request out of the prefix cache: no probe on
    /// admission, no snapshots inserted (privacy-sensitive prompts /
    /// cache-pollution control). Tokens are identical either way — the
    /// cache only moves TTFT — so this is purely a policy knob.
    pub no_cache: bool,
    /// time-to-first-token deadline: if no token has been produced
    /// this many ms after submission, the request finishes
    /// [`FinishReason::DeadlineExceeded`] at the next tick boundary
    /// (checked against the engine's injectable clock). `None` = no
    /// TTFT deadline.
    pub ttft_deadline_ms: Option<f64>,
    /// total-latency deadline (submission → last token). On expiry the
    /// request keeps whatever tokens it already generated and finishes
    /// [`FinishReason::DeadlineExceeded`]. `None` falls back to the
    /// engine's `default_deadline_ms` (0 = unbounded).
    pub deadline_ms: Option<f64>,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            temperature: 0.0,
            top_k: 0,
            seed: 0,
            no_cache: false,
            ttft_deadline_ms: None,
            deadline_ms: None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<u16>,
    pub max_new_tokens: usize,
    pub params: SamplingParams,
    /// stop at EOS (token 2)
    pub stop_at_eos: bool,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    Length,
    Eos,
    /// cancelled by the client (mid-queue or mid-flight); the response
    /// keeps the tokens generated so far
    Cancelled,
    /// shed at admission: the bounded submit queue was full
    /// (`NativeEngineConfig::max_queue`)
    Rejected,
    /// TTFT or total-latency deadline expired at a tick boundary
    DeadlineExceeded,
    /// the request's own execution panicked (isolated via
    /// `catch_unwind`; `Response::error` carries the panic payload) or
    /// its admission-time allocation failed
    Failed,
}

impl FinishReason {
    /// Natural completion (the request produced its full answer).
    /// Everything else is a failure-model outcome.
    pub fn is_ok(self) -> bool {
        matches!(self, FinishReason::Length | FinishReason::Eos)
    }
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: RequestId,
    pub tokens: Vec<u16>,
    pub finish: FinishReason,
    pub ttft_ms: f64,
    /// mean time-per-output-token over the decode phase
    pub tpot_ms: f64,
    /// time to last token (prefill + decode)
    pub ttlt_ms: f64,
    /// per-token inter-token gaps (decode phase, ms) — the raw samples
    /// behind the ITL percentiles; [`Self::itl_max_ms`] is the burst
    /// head-of-line-blocking quantity (a long prefill stalling decode
    /// shows up here, not in the mean)
    pub itl_ms: Vec<f64>,
    /// failure detail for non-`is_ok` finishes: the panic payload for
    /// `Failed`, a human-readable cause for `Rejected` /
    /// `DeadlineExceeded` / `Cancelled`. `None` on natural completion.
    pub error: Option<String>,
    /// when the request entered the engine queue (clock-relative ms —
    /// the per-request timeline, ISSUE 9; NaN on [`Self::terminal`]
    /// responses, which never carried stamps)
    pub queued_ms: f64,
    /// when admission moved it into the live set
    pub admitted_ms: f64,
    /// when its first token was sampled (NaN if none was)
    pub first_token_ms: f64,
    /// when it reached its terminal outcome
    pub finished_ms: f64,
}

impl Response {
    /// Worst inter-token gap this request observed (NaN when the
    /// request produced fewer than two tokens).
    pub fn itl_max_ms(&self) -> f64 {
        self.itl_ms.iter().copied().fold(f64::NAN, f64::max)
    }

    /// A terminal failure response for a request that never entered
    /// (or never re-enters) the live set: rejected at admission, shed
    /// from the queue, cancelled before its first tick, or failed
    /// allocation. No tokens, no latency samples.
    pub fn terminal(id: RequestId, finish: FinishReason, error: impl Into<String>) -> Response {
        Response {
            id,
            tokens: Vec::new(),
            finish,
            ttft_ms: f64::NAN,
            tpot_ms: f64::NAN,
            ttlt_ms: f64::NAN,
            itl_ms: Vec::new(),
            error: Some(error.into()),
            queued_ms: f64::NAN,
            admitted_ms: f64::NAN,
            first_token_ms: f64::NAN,
            finished_ms: f64::NAN,
        }
    }

    /// One-line per-request timeline (the `serve_batch --verbose`
    /// format): clock-relative queue/admit/first-token/finish stamps
    /// plus outcome and token count.
    pub fn timeline(&self) -> String {
        format!(
            "req {:>4}  queued={:.2}ms admitted={:.2}ms first-token={:.2}ms \
             finished={:.2}ms  {:?} ({} tokens)",
            self.id,
            self.queued_ms,
            self.admitted_ms,
            self.first_token_ms,
            self.finished_ms,
            self.finish,
            self.tokens.len(),
        )
    }
}

/// Per-lane speculative-decoding bookkeeping (ISSUE 10). A decoding
/// lane *attaches* one of these when the engine runs with a draft
/// model and a slot is free in the draft-state pool; it keeps it until
/// harvest (the draft slot is released in `finish_live`, the one
/// slot-reclaim point).
///
/// The two cursors count **stream tokens** (prompt ++ generated)
/// consumed by each model's state slab:
/// * the target slab always holds `target_next` consumed tokens with
///   `stream[target_next..]` still pending — exactly the plain-decode
///   pending-token invariant (`target_next == stream_len - 1` between
///   rounds), so a verify chunk is `stream[target_next..] ++ drafts`
///   and a rejected round restores the pre-verify snapshot (O(1),
///   constant-size — the SSM rollback asset) leaving `target_next`
///   untouched;
/// * the draft slab lags at `draft_next ≤ stream_len - 1` and catches
///   up through a batched prefill before proposing, so a round that
///   emitted nothing (fault isolation) needs no draft-side rollback at
///   all.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpecState {
    /// this lane's slot in the engine's draft-state pool
    pub draft_slot: usize,
    /// stream tokens consumed by the target slab (pending-token
    /// invariant: equals `prompt.len() + generated.len() - 1` between
    /// rounds)
    pub target_next: usize,
    /// stream tokens consumed by the draft slab (lags `target_next`;
    /// catch-up prefill closes the gap each round)
    pub draft_next: usize,
    /// current per-lane draft length ask — adapted by the engine:
    /// halved on rejection, +1 on full acceptance (capped at the
    /// configured `spec_tokens`), pinned to 0 once `dry_rounds`
    /// crosses the degrade threshold
    pub k: usize,
    /// consecutive rounds with zero accepted draft tokens; crossing
    /// the engine's threshold degrades the lane to plain decode
    /// (k = 0) permanently — adversarial prompts stop paying the
    /// draft cost
    pub dry_rounds: u32,
}

/// Where a live request sits in the unified scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// The prompt is still being consumed: `next` is the index of the
    /// first prompt token not yet prefilled (cache-restored prefixes
    /// start with `next > 0`). The scheduler advances it by one chunk
    /// per tick until `next == prompt.len()`.
    Prefilling { next: usize },
    /// First token sampled; the request joins the decode rounds.
    Decoding,
}

/// Engine-internal per-request state.
pub struct LiveRequest {
    pub req: Request,
    /// the prompt as the engine actually runs it (empty prompts are
    /// normalized to a lone BOS); chunked prefill indexes into this
    pub prompt: Vec<u16>,
    pub phase: Phase,
    /// engine-assigned admission order (monotonic). The live vec gets
    /// reordered by `swap_remove` at harvest, so FIFO policies (the
    /// chunk queue's budget order) must sort by this, not by position.
    pub admitted_seq: u64,
    pub generated: Vec<u16>,
    pub state_slot: usize,
    /// this request's private sampler stream — scheduling order cannot
    /// perturb it (see module docs)
    pub rng: Pcg32,
    /// submission time on the engine's injectable clock
    /// ([`crate::coordinator::faults::Clock`]); deadline sweeps compare
    /// against this, and it becomes `Response::queued_ms`
    pub submitted_ms: f64,
    /// when admission moved the request into the live set (same clock)
    pub admitted_ms: f64,
    /// failure-model verdict set by the engine (cancellation, deadline
    /// expiry, isolated panic). A set verdict overrides the natural
    /// finish reason in [`Self::into_response`] and marks the request
    /// for harvest this tick.
    pub fault: Option<(FinishReason, String)>,
    /// first-token stamp (engine clock); `None` until prefill completes
    pub prefill_done_ms: Option<f64>,
    /// last sampled-token stamp (engine clock) — the ITL gap anchor
    pub last_token_ms: Option<f64>,
    pub decode_ms: Vec<f64>,
    /// speculative-decoding state: `Some` once a decoding lane attaches
    /// a draft slot (engine configured with `spec_tokens > 0` and a
    /// draft model), `None` on the plain decode path
    pub spec: Option<SpecState>,
}

/// Derive a per-request sampler stream seed. Splitmix-style mixing so
/// nearby request ids land far apart, while staying a pure function of
/// (engine seed, request id, per-request seed) — reruns of the same
/// workload reproduce the same streams.
fn stream_seed(sampler_seed: u64, id: RequestId, param_seed: u64) -> u64 {
    let mut z = sampler_seed
        .wrapping_add(id.wrapping_mul(0x9E37_79B9_7F4A_7C15))
        .wrapping_add(param_seed.rotate_left(31));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl LiveRequest {
    /// `sampler_seed` is the engine-level seed
    /// (`NativeEngineConfig::sampler_seed` / `EngineConfig::sampler_seed`);
    /// the request's private RNG stream is derived from it together
    /// with the request id and `SamplingParams::seed`.
    pub fn new(req: Request, state_slot: usize, sampler_seed: u64) -> Self {
        let rng = Pcg32::new(stream_seed(sampler_seed, req.id, req.params.seed));
        let prompt =
            if req.prompt.is_empty() { vec![crate::data::BOS] } else { req.prompt.clone() };
        LiveRequest {
            prompt,
            phase: Phase::Prefilling { next: 0 },
            admitted_seq: 0,
            generated: Vec::new(),
            state_slot,
            rng,
            submitted_ms: 0.0,
            admitted_ms: 0.0,
            fault: None,
            prefill_done_ms: None,
            last_token_ms: None,
            decode_ms: Vec::new(),
            spec: None,
            req,
        }
    }

    pub fn next_input_token(&self) -> u16 {
        *self
            .generated
            .last()
            .unwrap_or_else(|| self.prompt.last().expect("empty prompt"))
    }

    /// Prompt tokens not yet consumed by prefill.
    pub fn prefill_remaining(&self) -> usize {
        match self.phase {
            Phase::Prefilling { next } => self.prompt.len() - next,
            Phase::Decoding => 0,
        }
    }

    pub fn done(&self) -> bool {
        self.phase == Phase::Decoding
            && (self.generated.len() >= self.req.max_new_tokens
                || (self.req.stop_at_eos && self.generated.last() == Some(&crate::data::EOS)))
    }

    pub fn finish_reason(&self) -> FinishReason {
        if self.req.stop_at_eos && self.generated.last() == Some(&crate::data::EOS) {
            FinishReason::Eos
        } else {
            FinishReason::Length
        }
    }

    /// `now_ms` is the harvest-time stamp on the owning engine's clock
    /// — the same clock every other stamp in this request came from.
    pub fn into_response(self, now_ms: f64) -> Response {
        let ttft = self.prefill_done_ms.map(|t| t - self.submitted_ms).unwrap_or(f64::NAN);
        let tpot = if self.decode_ms.is_empty() {
            f64::NAN
        } else {
            self.decode_ms.iter().sum::<f64>() / self.decode_ms.len() as f64
        };
        // an engine-set fault verdict (cancel / deadline / isolated
        // panic) overrides the natural finish reason; the partial
        // token stream is kept either way
        let natural = self.finish_reason();
        let (finish, error) = match self.fault {
            Some((f, e)) => (f, Some(e)),
            None => (natural, None),
        };
        Response {
            id: self.req.id,
            tokens: self.generated,
            finish,
            ttft_ms: ttft,
            tpot_ms: tpot,
            ttlt_ms: now_ms - self.submitted_ms,
            itl_ms: self.decode_ms,
            error,
            queued_ms: self.submitted_ms,
            admitted_ms: self.admitted_ms,
            first_token_ms: self.prefill_done_ms.unwrap_or(f64::NAN),
            finished_ms: now_ms,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(max_new: usize) -> Request {
        Request {
            id: 1,
            prompt: vec![1, 5, 9],
            max_new_tokens: max_new,
            params: SamplingParams::default(),
            stop_at_eos: true,
        }
    }

    #[test]
    fn lifecycle_done_by_length() {
        let mut lr = LiveRequest::new(req(2), 0, 0);
        assert_eq!(lr.phase, Phase::Prefilling { next: 0 });
        assert_eq!(lr.prefill_remaining(), 3);
        // an in-flight prefill is never "done", whatever the counters say
        assert!(!lr.done());
        lr.phase = Phase::Decoding;
        assert!(!lr.done());
        assert_eq!(lr.next_input_token(), 9);
        lr.generated.push(7);
        assert_eq!(lr.next_input_token(), 7);
        assert!(!lr.done());
        lr.generated.push(8);
        assert!(lr.done());
        assert_eq!(lr.finish_reason(), FinishReason::Length);
        assert_eq!(lr.prefill_remaining(), 0);
    }

    #[test]
    fn lifecycle_done_by_eos() {
        let mut lr = LiveRequest::new(req(10), 0, 0);
        lr.phase = Phase::Decoding;
        lr.generated.push(crate::data::EOS);
        assert!(lr.done());
        assert_eq!(lr.finish_reason(), FinishReason::Eos);
    }

    #[test]
    fn empty_prompt_normalized_to_bos() {
        let r = Request { prompt: vec![], ..req(1) };
        let lr = LiveRequest::new(r, 0, 0);
        assert_eq!(lr.prompt, vec![crate::data::BOS]);
        assert_eq!(lr.next_input_token(), crate::data::BOS);
    }

    #[test]
    fn rng_streams_are_keyed_by_seed_and_id() {
        // same (engine seed, id, params.seed) → same stream; changing
        // any key moves it — the per-request determinism contract
        let draw = |sampler_seed: u64, id: u64, pseed: u64| {
            let params = SamplingParams { seed: pseed, ..Default::default() };
            let r = Request { id, params, ..req(1) };
            let mut lr = LiveRequest::new(r, 0, sampler_seed);
            (0..4).map(|_| lr.rng.next_u32()).collect::<Vec<_>>()
        };
        assert_eq!(draw(1, 2, 3), draw(1, 2, 3));
        assert_ne!(draw(1, 2, 3), draw(9, 2, 3), "engine seed must move the stream");
        assert_ne!(draw(1, 2, 3), draw(1, 7, 3), "request id must move the stream");
        assert_ne!(draw(1, 2, 3), draw(1, 2, 8), "params seed must move the stream");
    }

    #[test]
    fn response_itl_max() {
        let mut lr = LiveRequest::new(req(3), 0, 0);
        lr.phase = Phase::Decoding;
        lr.generated.extend([3, 4, 5]);
        lr.decode_ms.extend([1.0, 5.0, 2.0]);
        let resp = lr.into_response(10.0);
        assert_eq!(resp.itl_ms, vec![1.0, 5.0, 2.0]);
        assert_eq!(resp.itl_max_ms(), 5.0);
        let mut lr2 = LiveRequest::new(req(1), 0, 0);
        lr2.phase = Phase::Decoding;
        lr2.generated.push(3);
        assert!(lr2.into_response(10.0).itl_max_ms().is_nan());
    }

    #[test]
    fn response_timeline_stamps_come_from_the_engine_clock() {
        let mut lr = LiveRequest::new(req(2), 0, 0);
        lr.submitted_ms = 1.0;
        lr.admitted_ms = 2.0;
        lr.prefill_done_ms = Some(5.0);
        lr.phase = Phase::Decoding;
        lr.generated.extend([3, 4]);
        let resp = lr.into_response(9.0);
        assert_eq!(resp.queued_ms, 1.0);
        assert_eq!(resp.admitted_ms, 2.0);
        assert_eq!(resp.first_token_ms, 5.0);
        assert_eq!(resp.finished_ms, 9.0);
        assert_eq!(resp.ttft_ms, 4.0, "TTFT = first token - queued");
        assert_eq!(resp.ttlt_ms, 8.0, "TTLT = finished - queued");
        let line = resp.timeline();
        assert!(line.contains("queued=1.00ms"), "{line}");
        assert!(line.contains("first-token=5.00ms"), "{line}");
    }

    #[test]
    fn fault_verdict_overrides_natural_finish() {
        // a cancelled request keeps its partial tokens but reports the
        // engine's verdict, not Length/Eos
        let mut lr = LiveRequest::new(req(3), 0, 0);
        lr.phase = Phase::Decoding;
        lr.generated.extend([3, 4]);
        lr.fault = Some((FinishReason::Cancelled, "cancelled by client".into()));
        let resp = lr.into_response(10.0);
        assert_eq!(resp.finish, FinishReason::Cancelled);
        assert_eq!(resp.tokens, vec![3, 4]);
        assert_eq!(resp.error.as_deref(), Some("cancelled by client"));
        assert!(!resp.finish.is_ok());
        assert!(FinishReason::Length.is_ok() && FinishReason::Eos.is_ok());
    }

    #[test]
    fn terminal_response_is_empty_and_typed() {
        let resp = Response::terminal(7, FinishReason::Rejected, "queue full");
        assert_eq!(resp.id, 7);
        assert!(resp.tokens.is_empty());
        assert_eq!(resp.finish, FinishReason::Rejected);
        assert_eq!(resp.error.as_deref(), Some("queue full"));
        assert!(resp.ttft_ms.is_nan() && resp.ttlt_ms.is_nan());
    }
}
