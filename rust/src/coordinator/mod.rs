//! L3 coordinator: the serving-framework layer (DESIGN.md §8).
//!
//! The paper is an inference/deployment paper, so the coordination
//! contribution is a serving runtime shaped like a miniature vLLM
//! router for SSMs:
//!
//! * [`request`]  — request/response types + lifecycle
//! * [`state`]    — the SSM state manager (constant bytes/request) and
//!                  the KV-cache pool (linear bytes/request) — the two
//!                  memory models behind paper Figure 1(c)
//! * [`batcher`]  — bucketed continuous batching for the decode loop +
//!                  the unified mixed decode/prefill tick planner
//!                  (`plan_tick`: token budget, prefill chunks)
//! * [`sampler`]  — greedy / temperature / top-k sampling (per-request
//!                  RNG streams on the native path)
//! * [`metrics`]  — TTFT / TPOT / ITL / TTLT as mergeable
//!                  constant-memory log₂ histograms
//!                  ([`crate::obs::hist`]) + per-tick duration and
//!                  queue-depth gauges, snapshotted across the mailbox
//!                  as a typed [`metrics::MetricsSnapshot`]
//! * [`engine`]   — the single-owner execution loop over [`crate::runtime`]
//!                  (two-phase: fixed-length AOT prefill graphs cannot
//!                  pause mid-prompt)
//! * [`native`]   — the artifact-free backend: the same engine surface
//!                  served from the pure-rust [`crate::ssm::StepModel`]s
//!                  (fp32 reference or W8A8) through ONE step-loop that
//!                  interleaves (B, T) chunked prefill with decode —
//!                  long prompts advance incrementally instead of
//!                  stalling live lanes
//! * [`server`]   — a threaded front door (std::mpsc; tokio is not in
//!                  the offline vendor set, and one executor thread is
//!                  the right shape for one PJRT CPU device anyway)
//! * [`faults`]   — deterministic fault injection for the chaos suite:
//!                  seeded, stateless per-(site, request, step) panic /
//!                  alloc-failure / snapshot-corruption / latency
//!                  decisions behind a zero-cost disabled default
//!
//! Both engines admit requests through the prefix-sharing snapshot
//! cache ([`crate::cache`]) when `cache_bytes > 0`: constant-size SSM
//! state makes whole-prompt snapshots O(1), so shared-prefix traffic
//! prefills only suffixes (native) or skips prefill entirely on exact
//! resubmission (both) — bit-identically to the cold path.

pub mod batcher;
pub mod engine;
pub mod engine_tr;
pub mod faults;
pub mod metrics;
pub mod native;
pub mod request;
pub mod sampler;
pub mod server;
pub mod state;

pub use engine::{Engine, EngineConfig};
pub use faults::{Clock, FaultPlan, FaultSite, TargetedFault};
pub use metrics::MetricsSnapshot;
pub use native::{NativeEngine, NativeEngineConfig, SpecDraft};
pub use request::{FinishReason, Phase, Request, RequestId, Response, SamplingParams, SpecState};
