//! Benchmark harness (criterion substitute): warmup + timed iterations
//! + summary stats, plus markdown table printers and seeded workload
//! generators shared by `rust/benches/*` and the examples.

use std::time::Instant;

use crate::util::rng::Pcg32;
use crate::util::stats::Summary;

/// Open the runtime for a bench, or explain how to build artifacts.
/// Benches print a skip notice (and exit 0) when the artifact tree
/// lacks what they need — `make artifacts` builds the full matrix.
pub fn open_runtime_or_skip(what: &str) -> Option<crate::runtime::Runtime> {
    let root = crate::config::Manifest::default_root();
    match crate::runtime::Runtime::new(&root) {
        Ok(rt) => Some(rt),
        Err(e) => {
            println!("[skip] {what}: {e:#} (run `make artifacts`)");
            None
        }
    }
}

/// True when the manifest contains a graph for (tier, method, kind).
pub fn have_graph(rt: &crate::runtime::Runtime, tier: &str, method: &str, kind: &str) -> bool {
    rt.manifest()
        .graphs
        .values()
        .any(|g| g.tier == tier && g.method == method && g.kind == kind)
}

/// Mamba tiers in size order (the paper's row order), jamba excluded.
pub fn tier_order(rt: &crate::runtime::Runtime) -> Vec<String> {
    let mut v: Vec<_> = rt
        .manifest()
        .tiers
        .values()
        .filter(|t| t.name != "jamba")
        .map(|t| (t.n_params, t.name.clone()))
        .collect();
    v.sort();
    v.into_iter().map(|(_, n)| n).collect()
}

/// Iteration counts trimmed by QUAMBA_BENCH_FAST=1.
pub fn iters(default: usize) -> usize {
    if std::env::var("QUAMBA_BENCH_FAST").is_ok() {
        (default / 4).max(2)
    } else {
        default
    }
}

/// Time `f` over `iters` iterations after `warmup` unrecorded runs.
/// Returns per-iteration milliseconds.
pub fn bench_ms<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    Summary::of(&samples)
}

/// Adaptive variant: run until `budget_s` elapses (min 3 iterations).
pub fn bench_ms_budget<F: FnMut()>(warmup: usize, budget_s: f64, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let start = Instant::now();
    let mut samples = Vec::new();
    while samples.len() < 3 || start.elapsed().as_secs_f64() < budget_s {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
        if samples.len() >= 10_000 {
            break;
        }
    }
    Summary::of(&samples)
}

/// Markdown table printer matching the paper's row/column layout.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    pub fn print(&self) {
        println!("\n### {}\n", self.title);
        let widths: Vec<usize> = (0..self.header.len())
            .map(|i| {
                self.rows
                    .iter()
                    .map(|r| r.get(i).map(|c| c.len()).unwrap_or(0))
                    .chain(std::iter::once(self.header[i].len()))
                    .max()
                    .unwrap_or(4)
            })
            .collect();
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, w) in widths.iter().enumerate() {
                let c = cells.get(i).map(|x| x.as_str()).unwrap_or("");
                s.push_str(&format!(" {c:w$} |"));
            }
            s
        };
        println!("{}", fmt_row(&self.header));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{:-<1$}|", "", w + 2));
        }
        println!("{sep}");
        for r in &self.rows {
            println!("{}", fmt_row(r));
        }
    }
}

/// Format helpers.
pub fn ms(x: f64) -> String {
    if x.is_nan() {
        "-".into()
    } else if x >= 100.0 {
        format!("{x:.0}")
    } else if x >= 10.0 {
        format!("{x:.1}")
    } else {
        format!("{x:.2}")
    }
}

pub fn pct(x: f64) -> String {
    if x.is_nan() {
        "-".into()
    } else {
        format!("{:.1}%", 100.0 * x)
    }
}

pub fn f2(x: f64) -> String {
    if x.is_nan() {
        "-".into()
    } else {
        format!("{x:.2}")
    }
}

/// The chunked-prefill head-of-line-blocking scenario, shared by
/// `benches/perf_native_decode.rs` and `examples/serve_batch.rs
/// --burst` so the CI trajectory key (`burst_itl_max`) and the CLI
/// demo measure the identical workload: `n_dec` short-prompt requests
/// decode `max_new` tokens each; at tick 8, `burst_n` prompts of
/// `burst_len` tokens land at once. Returns the max inter-token gap
/// (ms) observed by the *initially-decoding* requests — the quantity
/// `NativeEngineConfig::prefill_chunk` bounds (the engine guarantees
/// tokens are identical at any chunk size; only this gap moves).
pub fn burst_itl_max(
    model: Box<dyn crate::ssm::StepModel + Send + Sync>,
    cfg: crate::coordinator::NativeEngineConfig,
    n_dec: usize,
    max_new: usize,
    burst_n: usize,
    burst_len: usize,
    seed: u64,
) -> anyhow::Result<f64> {
    burst_itl_max_report(model, cfg, n_dec, max_new, burst_n, burst_len, seed).map(|(gap, _)| gap)
}

/// [`burst_itl_max`] plus the engine's end-of-run metrics report —
/// under `--fault-seed`/`--fault-rate` (serve_batch) the report's
/// `failures` line shows rejected/deadline/cancelled/failed counts and
/// the shed rate for the burst run.
pub fn burst_itl_max_report(
    model: Box<dyn crate::ssm::StepModel + Send + Sync>,
    cfg: crate::coordinator::NativeEngineConfig,
    n_dec: usize,
    max_new: usize,
    burst_n: usize,
    burst_len: usize,
    seed: u64,
) -> anyhow::Result<(f64, String)> {
    use crate::coordinator::{NativeEngine, Request, SamplingParams};
    // burst requests live above this id so the gap fold can filter
    // down to the initially-decoding lanes
    const BURST_ID_BASE: u64 = 1_000_000;
    let vocab = model.tier().vocab as u32;
    let mut eng = NativeEngine::new(model, cfg);
    let mut r = Pcg32::new(seed);
    let mut mk = |r: &mut Pcg32, len: usize| -> Vec<u16> {
        (0..len).map(|_| r.below(vocab) as u16).collect()
    };
    for i in 0..n_dec as u64 {
        eng.submit(Request {
            id: i,
            prompt: mk(&mut r, 8),
            max_new_tokens: max_new,
            params: SamplingParams::default(),
            stop_at_eos: false,
        });
    }
    let mut done = Vec::new();
    let mut tick = 0usize;
    while eng.n_live() + eng.n_queued() > 0 {
        if tick == 8 {
            // the burst: long prompts arriving mid-decode
            for j in 0..burst_n as u64 {
                eng.submit(Request {
                    id: BURST_ID_BASE + j,
                    prompt: mk(&mut r, burst_len),
                    max_new_tokens: 4,
                    params: SamplingParams::default(),
                    stop_at_eos: false,
                });
            }
        }
        done.extend(eng.step()?);
        tick += 1;
    }
    let gap = done
        .iter()
        .filter(|resp| resp.id < BURST_ID_BASE)
        .map(|resp| resp.itl_max_ms())
        .fold(f64::NAN, f64::max);
    Ok((gap, eng.metrics.report()))
}

/// Poisson-arrival request workload generator (serving benches).
pub struct Workload {
    pub prompts: Vec<Vec<u16>>,
    pub arrival_s: Vec<f64>,
    pub max_new: usize,
}

impl Workload {
    /// `rate` requests/second over `n` requests; prompts sampled from a
    /// token stream with lengths in [min_len, max_len].
    pub fn poisson(
        stream: &[u16],
        n: usize,
        rate: f64,
        min_len: usize,
        max_len: usize,
        max_new: usize,
        seed: u64,
    ) -> Workload {
        let mut rng = Pcg32::new(seed);
        let mut prompts = Vec::with_capacity(n);
        let mut arrival_s = Vec::with_capacity(n);
        let mut t = 0.0f64;
        for _ in 0..n {
            let len = min_len + rng.below((max_len - min_len + 1) as u32) as usize;
            let start = rng.below((stream.len() - len) as u32) as usize;
            prompts.push(stream[start..start + len].to_vec());
            t += rng.exp(rate);
            arrival_s.push(t);
        }
        Workload { prompts, arrival_s, max_new }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let s = bench_ms(1, 5, || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert_eq!(s.n, 5);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new("Test", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print(); // smoke: no panic
    }

    #[test]
    fn workload_deterministic() {
        let stream: Vec<u16> = (0..1000u16).collect();
        let w1 = Workload::poisson(&stream, 10, 5.0, 4, 16, 32, 9);
        let w2 = Workload::poisson(&stream, 10, 5.0, 4, 16, 32, 9);
        assert_eq!(w1.prompts, w2.prompts);
        assert_eq!(w1.arrival_s, w2.arrival_s);
        assert!(w1.arrival_s.windows(2).all(|w| w[0] <= w[1]));
    }
}
