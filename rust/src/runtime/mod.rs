//! PJRT runtime: load AOT-compiled HLO text, upload weights once as
//! device buffers, execute prefill/decode steps from the rust hot path.
//!
//! Pattern adapted from /opt/xla-example/load_hlo: HLO *text* is the
//! interchange format (`HloModuleProto::from_text_file` reassigns
//! instruction ids, sidestepping the 64-bit-id protos jax ≥0.5 emits).
//!
//! Threading: `PjRtClient` is `Rc`-based (not `Send`), so one
//! [`Runtime`] lives on a dedicated executor thread inside the
//! coordinator; everything else talks to it over channels — the same
//! single-owner discipline a GPU stream requires.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{GraphInfo, Manifest};
use crate::tensor::{qtz, DType, Tensor};

/// Host→device bridge for one graph + its resident weight buffers.
pub struct LoadedModel {
    pub info: GraphInfo,
    exe: xla::PjRtLoadedExecutable,
    /// weights uploaded once; passed by reference on every execute
    weight_bufs: Vec<xla::PjRtBuffer>,
    /// host literals backing the uploads — `execute_b` does NOT await
    /// the host→device transfer, so the source literal must stay alive
    /// as long as the buffer may still be read (see xla_rs.cc:execute)
    _weight_lits: Vec<xla::Literal>,
    pub weight_bytes: usize,
    pub compile_ms: f64,
}

fn dtype_to_elem(d: DType) -> xla::ElementType {
    match d {
        DType::F32 => xla::ElementType::F32,
        DType::I8 => xla::ElementType::S8,
        DType::I32 => xla::ElementType::S32,
        DType::U16 => xla::ElementType::U16,
        DType::I64 => xla::ElementType::S64,
        DType::U8 => xla::ElementType::U8,
    }
}

fn elem_to_dtype(e: xla::ElementType) -> Option<DType> {
    Some(match e {
        xla::ElementType::F32 => DType::F32,
        xla::ElementType::S8 => DType::I8,
        xla::ElementType::S32 => DType::I32,
        xla::ElementType::U16 => DType::U16,
        xla::ElementType::S64 => DType::I64,
        xla::ElementType::U8 => DType::U8,
        _ => return None,
    })
}

pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    xla::Literal::create_from_shape_and_untyped_data(dtype_to_elem(t.dtype), &t.shape, &t.data)
        .map_err(|e| anyhow!("literal create failed: {e:?}"))
}

pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit
        .array_shape()
        .map_err(|e| anyhow!("literal shape: {e:?}"))?;
    let dtype = elem_to_dtype(shape.element_type())
        .ok_or_else(|| anyhow!("unsupported element type {:?}", shape.element_type()))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let n: usize = dims.iter().product();
    let mut bytes = vec![0u8; n * dtype.itemsize()];
    match dtype {
        DType::F32 => {
            let mut v = vec![0f32; n];
            lit.copy_raw_to(&mut v).map_err(|e| anyhow!("copy_raw: {e:?}"))?;
            for (i, x) in v.iter().enumerate() {
                bytes[i * 4..(i + 1) * 4].copy_from_slice(&x.to_le_bytes());
            }
        }
        DType::I32 => {
            let mut v = vec![0i32; n];
            lit.copy_raw_to(&mut v).map_err(|e| anyhow!("copy_raw: {e:?}"))?;
            for (i, x) in v.iter().enumerate() {
                bytes[i * 4..(i + 1) * 4].copy_from_slice(&x.to_le_bytes());
            }
        }
        DType::I8 => {
            let mut v = vec![0i8; n];
            lit.copy_raw_to(&mut v).map_err(|e| anyhow!("copy_raw: {e:?}"))?;
            for (i, x) in v.iter().enumerate() {
                bytes[i] = *x as u8;
            }
        }
        DType::U8 => {
            lit.copy_raw_to(&mut bytes).map_err(|e| anyhow!("copy_raw: {e:?}"))?;
        }
        DType::U16 => {
            let mut v = vec![0u16; n];
            lit.copy_raw_to(&mut v).map_err(|e| anyhow!("copy_raw: {e:?}"))?;
            for (i, x) in v.iter().enumerate() {
                bytes[i * 2..(i + 1) * 2].copy_from_slice(&x.to_le_bytes());
            }
        }
        DType::I64 => {
            let mut v = vec![0i64; n];
            lit.copy_raw_to(&mut v).map_err(|e| anyhow!("copy_raw: {e:?}"))?;
            for (i, x) in v.iter().enumerate() {
                bytes[i * 8..(i + 1) * 8].copy_from_slice(&x.to_le_bytes());
            }
        }
    }
    Ok(Tensor::new(dtype, dims, bytes))
}

/// The PJRT runtime: client + compile cache + weight-bundle cache.
pub struct Runtime {
    pub client: xla::PjRtClient,
    manifest: Manifest,
    models: BTreeMap<String, LoadedModel>,
    weight_tensors: BTreeMap<String, Vec<(String, Tensor)>>,
    pub stats: RuntimeStats,
}

#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub compiles: usize,
    pub executes: usize,
    pub compile_ms_total: f64,
    pub resident_weight_bytes: usize,
}

impl Runtime {
    pub fn new(artifacts_root: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_root).map_err(|e| anyhow!(e))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            manifest,
            models: BTreeMap::new(),
            weight_tensors: BTreeMap::new(),
            stats: RuntimeStats::default(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Load a weight bundle (cached) in manifest parameter order.
    fn weight_list(&mut self, key: &str) -> Result<&Vec<(String, Tensor)>> {
        if !self.weight_tensors.contains_key(key) {
            let info = self
                .manifest
                .weights
                .get(key)
                .ok_or_else(|| anyhow!("unknown weight bundle {key}"))?
                .clone();
            let q = qtz::load(&info.file).with_context(|| format!("loading {:?}", info.file))?;
            let mut list = Vec::with_capacity(info.params.len());
            for name in &info.params {
                let t = q
                    .get(name)
                    .ok_or_else(|| anyhow!("{key}: missing weight {name}"))?
                    .clone();
                list.push((name.clone(), t));
            }
            self.weight_tensors.insert(key.to_string(), list);
        }
        Ok(&self.weight_tensors[key])
    }

    /// Raw weight tensors of a bundle (for the rust reference sims).
    pub fn weight_qtz(&self, key: &str) -> Result<qtz::QtzFile> {
        let info = self
            .manifest
            .weights
            .get(key)
            .ok_or_else(|| anyhow!("unknown weight bundle {key}"))?;
        Ok(qtz::load(&info.file)?)
    }

    /// Compile a graph (cached) and upload its weights as device
    /// buffers (once per graph).
    pub fn load(&mut self, graph_name: &str) -> Result<&LoadedModel> {
        if !self.models.contains_key(graph_name) {
            let info = self
                .manifest
                .graphs
                .get(graph_name)
                .ok_or_else(|| anyhow!("unknown graph {graph_name}"))?
                .clone();
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(
                info.file.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )
            .map_err(|e| anyhow!("parse HLO {:?}: {e:?}", info.file))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {graph_name}: {e:?}"))?;
            let compile_ms = t0.elapsed().as_secs_f64() * 1e3;
            let wkey = info.weights_key.clone();
            // graphs with baked-in constants (e.g. the Jamba Table 4
            // combos) have no weight bundle
            let wl: Vec<(String, Tensor)> = if wkey.is_empty() {
                Vec::new()
            } else {
                self.weight_list(&wkey)?.clone()
            };
            let mut weight_bufs = Vec::with_capacity(wl.len());
            let mut weight_lits = Vec::with_capacity(wl.len());
            let mut weight_bytes = 0;
            for (_, t) in &wl {
                weight_bytes += t.nbytes();
                let lit = tensor_to_literal(t)?;
                let buf = self
                    .client
                    .buffer_from_host_literal(None, &lit)
                    .map_err(|e| anyhow!("weight upload: {e:?}"))?;
                weight_bufs.push(buf);
                weight_lits.push(lit);
            }
            self.stats.compiles += 1;
            self.stats.compile_ms_total += compile_ms;
            self.stats.resident_weight_bytes =
                self.stats.resident_weight_bytes.max(weight_bytes);
            self.models.insert(
                graph_name.to_string(),
                LoadedModel {
                    info,
                    exe,
                    weight_bufs,
                    _weight_lits: weight_lits,
                    weight_bytes,
                    compile_ms,
                },
            );
        }
        Ok(&self.models[graph_name])
    }

    pub fn is_loaded(&self, graph_name: &str) -> bool {
        self.models.contains_key(graph_name)
    }

    /// Execute a loaded graph on host tensors. `inputs` are the
    /// non-weight leading parameters (tokens, states, ...); weights are
    /// appended from the resident device buffers. Returns the output
    /// tuple elements as host tensors.
    pub fn execute(&mut self, graph_name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.load(graph_name)?;
        let model = &self.models[graph_name];
        // NB: keep the input literals alive until the outputs are
        // materialized — execute_b does not await the input transfers.
        let mut input_lits: Vec<xla::Literal> = Vec::with_capacity(inputs.len());
        let mut args: Vec<xla::PjRtBuffer> = Vec::with_capacity(inputs.len());
        for t in inputs {
            let lit = tensor_to_literal(t)?;
            args.push(
                self.client
                    .buffer_from_host_literal(None, &lit)
                    .map_err(|e| anyhow!("input upload: {e:?}"))?,
            );
            input_lits.push(lit);
        }
        let mut refs: Vec<&xla::PjRtBuffer> = args.iter().collect();
        refs.extend(model.weight_bufs.iter());
        let out = model
            .exe
            .execute_b(&refs)
            .map_err(|e| anyhow!("execute {graph_name}: {e:?}"))?;
        self.stats.executes += 1;
        let first = out
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("no replica output"))?;
        let mut tensors = Vec::new();
        if first.len() == 1 {
            // single tuple buffer: pull to host and split
            let lit = first[0]
                .to_literal_sync()
                .map_err(|e| anyhow!("to_literal: {e:?}"))?;
            if lit.array_shape().is_ok() {
                // plain array output (single-output graph)
                tensors.push(literal_to_tensor(&lit)?);
            } else {
                for e in lit.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))? {
                    tensors.push(literal_to_tensor(&e)?);
                }
            }
        } else {
            for buf in first {
                let lit = buf
                    .to_literal_sync()
                    .map_err(|e| anyhow!("to_literal: {e:?}"))?;
                tensors.push(literal_to_tensor(&lit)?);
            }
        }
        if tensors.is_empty() {
            bail!("graph {graph_name} produced no outputs");
        }
        drop(input_lits); // outputs are on host; transfers are done
        Ok(tensors)
    }

    /// Total bytes of a tier+method's resident weights (Table 1 size).
    pub fn model_bytes(&self, weights_key: &str) -> Option<usize> {
        self.manifest.weights.get(weights_key).map(|w| w.bytes)
    }

    /// Hot-path execute: literals in, literals out — skips the
    /// byte-level `Tensor` round-trips of [`Runtime::execute`] (§Perf:
    /// the decode loop moves ~1 MB of state per step at B=8; the typed
    /// literal path saves four per-element byte-conversion passes).
    pub fn execute_lit(
        &mut self,
        graph_name: &str,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        self.load(graph_name)?;
        let model = &self.models[graph_name];
        let mut args: Vec<xla::PjRtBuffer> = Vec::with_capacity(inputs.len());
        for lit in inputs {
            args.push(
                self.client
                    .buffer_from_host_literal(None, lit)
                    .map_err(|e| anyhow!("input upload: {e:?}"))?,
            );
        }
        let mut refs: Vec<&xla::PjRtBuffer> = args.iter().collect();
        refs.extend(model.weight_bufs.iter());
        let out = model
            .exe
            .execute_b(&refs)
            .map_err(|e| anyhow!("execute {graph_name}: {e:?}"))?;
        self.stats.executes += 1;
        let first = out
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("no replica output"))?;
        let mut lits = Vec::new();
        for buf in first {
            let lit = buf
                .to_literal_sync()
                .map_err(|e| anyhow!("to_literal: {e:?}"))?;
            if lit.array_shape().is_ok() {
                lits.push(lit);
            } else {
                lits.extend(lit.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))?);
            }
        }
        if lits.is_empty() {
            bail!("graph {graph_name} produced no outputs");
        }
        Ok(lits)
    }
}

/// Typed literal constructors/readers for the hot path (single copy,
/// no per-element byte packing).
pub fn lit_from_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    debug_assert_eq!(shape.iter().product::<usize>(), data.len());
    let mut lit = xla::Literal::create_from_shape(xla::PrimitiveType::F32, shape);
    lit.copy_raw_from(data).map_err(|e| anyhow!("copy_raw_from: {e:?}"))?;
    Ok(lit)
}

pub fn lit_from_i32(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    debug_assert_eq!(shape.iter().product::<usize>(), data.len());
    let mut lit = xla::Literal::create_from_shape(xla::PrimitiveType::S32, shape);
    lit.copy_raw_from(data).map_err(|e| anyhow!("copy_raw_from: {e:?}"))?;
    Ok(lit)
}

pub fn lit_to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    let n = lit.element_count();
    let mut v = vec![0f32; n];
    lit.copy_raw_to(&mut v).map_err(|e| anyhow!("copy_raw_to: {e:?}"))?;
    Ok(v)
}
