"""Jamba-like hybrid model (paper §5.5, Table 4): interleaved
self-attention, Mamba, and top-2-of-4 MoE blocks.

The paper's Table 4 asks which *combination* of per-block-type
quantizers keeps the hybrid usable:

    attention ∈ {FP16, LLM.int8, SmQ}
    mamba     ∈ {FP16, LLM.int8, Quamba}
    moe       ∈ {FP16, LLM.int8}

LLM.int8-style mixed-precision decomposition lives in
`quant/mixed.py`; "LLM.int8 on Mamba" means applying it naively to the
Mamba linears while leaving the SSM input/output activations at plain
static int8 — the configuration the paper reports as `fail`, because
the decomposition never addresses the x/y sensitivity. Quamba-on-Mamba
uses the full recipe from `model.py`.

Layer pattern (L blocks): attention at indices ≡ 0 (mod 4), MoE MLP
after every block (as in Jamba, each block = mixer + MoE/MLP), Mamba
elsewhere.
"""

from __future__ import annotations

import math
from collections import OrderedDict
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from .kernels import ref
from .quant import core as qc
from .quant import hadamard_util as hu
from .quant.mixed import matmul_mixed, outlier_columns, split_weight


@dataclass(frozen=True)
class JambaTier:
    name: str
    d_model: int = 96
    n_layer: int = 4
    n_head: int = 4
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    n_experts: int = 4
    top_k: int = 2
    vocab: int = data_mod.VOCAB_SIZE
    eps: float = 1e-5

    @property
    def d_inner(self):
        return self.expand * self.d_model

    @property
    def dt_rank(self):
        return max(1, math.ceil(self.d_model / 16))

    @property
    def d_ff(self):
        return 2 * self.d_model

    def attn_layers(self):
        return [i for i in range(self.n_layer) if i % 4 == 0]

    def n_params(self) -> int:
        d, di, r, n, w = self.d_model, self.d_inner, self.dt_rank, self.d_state, self.d_conv
        mamba = d + d * 2 * di + w * di + di + di * (r + 2 * n) + r * di + di + di * n + di + di * d
        attn = d + 4 * d * d
        moe = d + d * self.n_experts + self.n_experts * (2 * d * self.d_ff + self.d_ff)
        n_attn = len(self.attn_layers())
        return self.vocab * d + d + n_attn * attn + (self.n_layer - n_attn) * mamba + self.n_layer * moe


JAMBA_TIER = JambaTier("jamba")


def init_params(cfg: JambaTier, seed: int = 5) -> "OrderedDict[str, np.ndarray]":
    rng = np.random.default_rng(seed)
    P: "OrderedDict[str, np.ndarray]" = OrderedDict()

    def dense(shape, scale=None):
        s = scale if scale is not None else 1.0 / math.sqrt(shape[0])
        return rng.uniform(-s, s, size=shape).astype(np.float32)

    d, di, r, n, w, ff = cfg.d_model, cfg.d_inner, cfg.dt_rank, cfg.d_state, cfg.d_conv, cfg.d_ff
    P["embedding.weight"] = rng.normal(0, 0.02, size=(cfg.vocab, d)).astype(np.float32)
    attn_set = set(cfg.attn_layers())
    for i in range(cfg.n_layer):
        p = f"layers.{i}."
        P[p + "norm.weight"] = np.ones(d, np.float32)
        if i in attn_set:
            P[p + "wqkv"] = dense((d, 3 * d))
            P[p + "wo"] = dense((d, d))
        else:
            P[p + "in_proj.weight"] = dense((d, 2 * di))
            P[p + "conv1d.weight"] = dense((w, di), scale=1 / math.sqrt(w))
            P[p + "conv1d.bias"] = np.zeros(di, np.float32)
            P[p + "x_proj.weight"] = dense((di, r + 2 * n))
            P[p + "dt_proj.weight"] = dense((r, di), scale=r**-0.5)
            dt = np.exp(rng.uniform(math.log(1e-3), math.log(1e-1), size=di))
            P[p + "dt_proj.bias"] = (dt + np.log(-np.expm1(-dt))).astype(np.float32)
            P[p + "A_log"] = np.log(np.tile(np.arange(1, n + 1, dtype=np.float32), (di, 1)))
            P[p + "D"] = np.ones(di, np.float32)
            P[p + "out_proj.weight"] = dense((di, d))
        # MoE after every block
        P[p + "moe_norm.weight"] = np.ones(d, np.float32)
        P[p + "router"] = dense((d, cfg.n_experts))
        for e in range(cfg.n_experts):
            P[p + f"expert{e}.w1"] = dense((d, ff))
            P[p + f"expert{e}.b1"] = np.zeros(ff, np.float32)
            P[p + f"expert{e}.w2"] = dense((ff, d))
    P["norm_f.weight"] = np.ones(d, np.float32)
    return P


def _attn_block(cfg, P, p, h):
    """Causal attention with ALiBi (shared shape with transformer.py)."""
    B, T, d = h.shape
    H, Dh = cfg.n_head, cfg.d_model // cfg.n_head
    qkv = h @ P[p + "wqkv"]
    q, k, v = jnp.split(qkv.reshape(B, T, 3, H, Dh), 3, axis=2)
    q, k, v = q[:, :, 0], k[:, :, 0], v[:, :, 0]
    slopes = jnp.asarray([2.0 ** (-(i + 1) * 8.0 / H) for i in range(H)], jnp.float32)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(Dh)
    dist = jnp.arange(T)[:, None] - jnp.arange(T)[None, :]
    bias = -slopes[:, None, None] * jnp.maximum(dist, 0)
    logits = jnp.where((dist >= 0)[None, None], logits + bias[None], -1e9)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(B, T, d)
    return out @ P[p + "wo"]


def _mamba_block(cfg, P, p, h):
    di, n, r, W = cfg.d_inner, cfg.d_state, cfg.dt_rank, cfg.d_conv
    xz = h @ P[p + "in_proj.weight"]
    x, z = xz[..., :di], xz[..., di:]
    pads = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    conv = sum(pads[:, j : j + x.shape[1], :] * P[p + "conv1d.weight"][j][None, None, :]
               for j in range(W))
    xs = ref.silu(conv + P[p + "conv1d.bias"][None, None, :])
    bcdt = xs @ P[p + "x_proj.weight"]
    dt = ref.softplus(bcdt[..., :r] @ P[p + "dt_proj.weight"] + P[p + "dt_proj.bias"])
    A = -jnp.exp(P[p + "A_log"])
    y, _ = ref.selective_scan(xs, dt, A, bcdt[..., r : r + n], bcdt[..., r + n :], P[p + "D"])
    return (y * ref.silu(z)) @ P[p + "out_proj.weight"]


def _moe_block(cfg, P, p, h, use_topk=False):
    """Top-k routed MoE MLP (dense compute, sparse mixture weights —
    exact for evaluation; a serving system would gather).

    Routing threshold via sort, not lax.top_k: the xla_extension 0.5.1
    HLO-text parser predates `topk(..., largest=true)`. Training sets
    `use_topk=True` (identical numerics) because this jax build cannot
    differentiate through sort's gather VJP."""
    gate = jax.nn.softmax(h @ P[p + "router"], axis=-1)     # (B,T,E)
    if use_topk:
        kth = jax.lax.top_k(gate, cfg.top_k)[0][..., -1:]
    else:
        kth = jnp.sort(gate, axis=-1)[..., -cfg.top_k : gate.shape[-1] - cfg.top_k + 1]
    mask = (gate >= kth).astype(gate.dtype)
    gate = gate * mask
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    out = 0.0
    for e in range(cfg.n_experts):
        hid = jax.nn.gelu(h @ P[p + f"expert{e}.w1"] + P[p + f"expert{e}.b1"])
        out = out + gate[..., e : e + 1] * (hid @ P[p + f"expert{e}.w2"])
    return out


def forward_fp(cfg: JambaTier, P, tokens, use_topk=False):
    """fp32 hybrid forward (prefill only — Table 4 is accuracy-only)."""
    resid = P["embedding.weight"][tokens]
    attn_set = set(cfg.attn_layers())
    for i in range(cfg.n_layer):
        p = f"layers.{i}."
        h = ref.rmsnorm(resid, P[p + "norm.weight"], cfg.eps)
        mixer = _attn_block(cfg, P, p, h) if i in attn_set else _mamba_block(cfg, P, p, h)
        resid = resid + mixer
        h2 = ref.rmsnorm(resid, P[p + "moe_norm.weight"], cfg.eps)
        resid = resid + _moe_block(cfg, P, p, h2, use_topk=use_topk)
    final = ref.rmsnorm(resid, P["norm_f.weight"], cfg.eps)
    return final @ P["embedding.weight"].T


# ---------------------------------------------------------------------------
# Quantized combinations (Table 4)
# ---------------------------------------------------------------------------

def calibrate(cfg: JambaTier, P, stream, n_samples=24, seqlen=96, batch=8, seed=11):
    """Collect per-site amax + per-channel amax for all linear inputs."""
    P_j = {k: jnp.asarray(v) for k, v in P.items()}
    sites: dict = {}
    chan: dict = {}

    def record(name, x):
        a = np.abs(np.asarray(x, np.float32))
        sites[name] = max(sites.get(name, 0.0), float(a.max()))
        cm = a.reshape(-1, a.shape[-1]).max(axis=0)
        chan[name] = np.maximum(chan.get(name, 0.0), cm)

    gen = data_mod.batches(stream, batch, seqlen, seed)
    attn_set = set(cfg.attn_layers())
    for _ in range(max(1, n_samples // batch)):
        x, _ = next(gen)
        resid = P_j["embedding.weight"][jnp.asarray(x)]
        for i in range(cfg.n_layer):
            p = f"layers.{i}."
            h = ref.rmsnorm(resid, P_j[p + "norm.weight"], cfg.eps)
            record(p + "mixer_in", h)
            if i in attn_set:
                mixer = _attn_block(cfg, P_j, p, h)
            else:
                mixer = _mamba_block(cfg, P_j, p, h)
                # tap mamba internals for quamba scales
                _tap_mamba(cfg, P_j, p, h, record)
            record(p + "mixer_out", mixer)
            resid = resid + mixer
            h2 = ref.rmsnorm(resid, P_j[p + "moe_norm.weight"], cfg.eps)
            record(p + "moe_in", h2)
            resid = resid + _moe_block(cfg, P_j, p, h2)
        record("head_in", ref.rmsnorm(resid, P_j["norm_f.weight"], cfg.eps))
    return sites, chan


def _tap_mamba(cfg, P, p, h, record):
    di, n, r, W = cfg.d_inner, cfg.d_state, cfg.dt_rank, cfg.d_conv
    xz = h @ P[p + "in_proj.weight"]
    x, z = xz[..., :di], xz[..., di:]
    pads = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    conv = sum(pads[:, j : j + x.shape[1], :] * P[p + "conv1d.weight"][j][None, None, :]
               for j in range(W))
    xs = ref.silu(conv + P[p + "conv1d.bias"][None, None, :])
    record(p + "x_ssm", xs)
    bcdt = xs @ P[p + "x_proj.weight"]
    record(p + "bcdt", bcdt)
    dt = ref.softplus(bcdt[..., :r] @ P[p + "dt_proj.weight"] + P[p + "dt_proj.bias"])
    A = -jnp.exp(P[p + "A_log"])
    y, _ = ref.selective_scan(xs, dt, A, bcdt[..., r : r + n], bcdt[..., r + n :], P[p + "D"])
    gated = y * ref.silu(z)
    record(p + "gated", gated)
    record(p + "gated_h", hu.fwht_jnp(gated))


def _q_linear_static(x, w, s_x, nbits=8):
    """plain static W8A8 linear (x fp in, quantize with s_x)."""
    wq, sw = qc.quantize_weight_np(np.asarray(w), nbits)
    return lambda xv: ref.matmul_i8(qc.quantize_sym(xv, s_x, nbits), jnp.asarray(wq), s_x, float(sw))


def build_combo(cfg: JambaTier, P, sites, chan, attn_mode: str, mamba_mode: str, moe_mode: str):
    """Return a jittable fp-in/fp-out forward implementing one Table 4
    combination. Modes: 'fp', 'int8' (LLM.int8 mixed), 'smq' (attn
    only), 'quamba' (mamba only)."""
    P_j = {k: jnp.asarray(v) for k, v in P.items()}
    attn_set = set(cfg.attn_layers())

    # precompute per-layer quantized operators
    ops: dict = {}
    for i in range(cfg.n_layer):
        p = f"layers.{i}."
        if i in attn_set and attn_mode in ("int8", "smq"):
            for leaf, site in [("wqkv", p + "mixer_in"), ("wo", p + "mixer_out")]:
                w = np.asarray(P[p + leaf], np.float32)
                cam = chan[site if leaf == "wqkv" else p + "mixer_out"]
                if attn_mode == "smq" and leaf == "wqkv":
                    from .quant.smoothquant import fold_linear

                    s, w = fold_linear(cam, w, 0.5)
                    ops[p + leaf + ".smooth"] = jnp.asarray(1.0 / s)
                    amax = float((cam / s).max())
                else:
                    ops[p + leaf + ".outliers"] = split_weight(w, outlier_columns(cam))
                    amax = float(np.median(cam) * 4 + 1e-6)
                if attn_mode == "smq" and leaf == "wqkv":
                    wq, sw = qc.quantize_weight_np(w)
                    ops[p + leaf] = (jnp.asarray(wq), float(sw), qc.scale_sym(amax, 8))
        if i not in attn_set and mamba_mode in ("int8", "quamba"):
            for leaf in ["in_proj.weight", "x_proj.weight", "dt_proj.weight", "out_proj.weight"]:
                w = np.asarray(P[p + leaf], np.float32)
                if mamba_mode == "quamba" and leaf == "out_proj.weight":
                    w = hu.hadamard_np(cfg.d_inner) @ w
                wq, sw = qc.quantize_weight_np(w)
                scale = float(sw) / (cfg.d_inner if (mamba_mode == "quamba" and leaf == "out_proj.weight") else 1)
                ops[p + leaf] = (jnp.asarray(wq), scale)
        if moe_mode == "int8":
            for e in range(cfg.n_experts):
                for leaf, site in [(f"expert{e}.w1", p + "moe_in")]:
                    w = np.asarray(P[p + leaf], np.float32)
                    ops[p + leaf + ".outliers"] = split_weight(w, outlier_columns(chan[site]))

    def fwd(tokens):
        resid = P_j["embedding.weight"][tokens]
        for i in range(cfg.n_layer):
            p = f"layers.{i}."
            h = ref.rmsnorm(resid, P_j[p + "norm.weight"], cfg.eps)
            if i in attn_set:
                mixer = _attn_combo(cfg, P_j, p, h, attn_mode, sites, ops)
            else:
                mixer = _mamba_combo(cfg, P_j, p, h, mamba_mode, sites, ops)
            resid = resid + mixer
            h2 = ref.rmsnorm(resid, P_j[p + "moe_norm.weight"], cfg.eps)
            resid = resid + _moe_combo(cfg, P_j, p, h2, moe_mode, sites, ops)
        final = ref.rmsnorm(resid, P_j["norm_f.weight"], cfg.eps)
        return final @ P_j["embedding.weight"].T

    return fwd


def _attn_combo(cfg, P, p, h, mode, sites, ops):
    if mode == "fp":
        return _attn_block(cfg, P, p, h)
    B, T, d = h.shape
    H, Dh = cfg.n_head, cfg.d_model // cfg.n_head
    if mode == "smq":
        h = h * ops[p + "wqkv.smooth"]
        wq, sw, s_x = ops[p + "wqkv"]
        qkv = ref.matmul_i8(qc.quantize_sym(h, s_x), wq, s_x, sw)
    else:  # int8 (LLM.int8 mixed)
        parts = ops[p + "wqkv.outliers"]
        s_x = qc.scale_sym(sites[p + "mixer_in"], 8)
        qkv = matmul_mixed(h, parts, float(s_x))
    q, k, v = jnp.split(qkv.reshape(B, T, 3, H, Dh), 3, axis=2)
    q, k, v = q[:, :, 0], k[:, :, 0], v[:, :, 0]
    slopes = jnp.asarray([2.0 ** (-(i + 1) * 8.0 / H) for i in range(H)], jnp.float32)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(Dh)
    dist = jnp.arange(T)[:, None] - jnp.arange(T)[None, :]
    bias = -slopes[:, None, None] * jnp.maximum(dist, 0)
    logits = jnp.where((dist >= 0)[None, None], logits + bias[None], -1e9)
    out = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(logits, -1), v).reshape(B, T, d)
    parts_o = ops.get(p + "wo.outliers")
    if parts_o is not None:
        s_o = qc.scale_sym(sites[p + "mixer_out"], 8)
        return matmul_mixed(out, parts_o, float(s_o))
    return out @ P[p + "wo"]


def _mamba_combo(cfg, P, p, h, mode, sites, ops):
    if mode == "fp":
        return _mamba_block(cfg, P, p, h)
    di, n, r, W = cfg.d_inner, cfg.d_state, cfg.dt_rank, cfg.d_conv
    s_in = qc.scale_sym(sites[p + "mixer_in"], 8)
    wq, sw = ops[p + "in_proj.weight"]
    xz = ref.matmul_i8(qc.quantize_sym(h, s_in), wq, float(s_in), sw)
    x, z = xz[..., :di], xz[..., di:]
    pads = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    conv = sum(pads[:, j : j + x.shape[1], :] * P[p + "conv1d.weight"][j][None, None, :]
               for j in range(W))
    xs = ref.silu(conv + P[p + "conv1d.bias"][None, None, :])
    if mode == "quamba":
        # percentile-clipped static x (the Quamba x-site recipe)
        s_x = qc.scale_sym(sites[p + "x_ssm"] * 0.7, 8)  # p≈99.9 proxy on amax
    else:
        s_x = qc.scale_sym(sites[p + "x_ssm"], 8)
    xs = qc.dequantize_sym(qc.quantize_sym(xs, s_x), s_x)
    wq, sw = ops[p + "x_proj.weight"]
    bcdt = ref.matmul_i8(qc.quantize_sym(xs, s_x), wq, float(s_x), sw)
    s_dt = qc.scale_sym(sites[p + "bcdt"], 8)
    wq2, sw2 = ops[p + "dt_proj.weight"]
    dt = ref.softplus(
        ref.matmul_i8(qc.quantize_sym(bcdt[..., :r], s_dt), wq2, float(s_dt), sw2)
        + P[p + "dt_proj.bias"]
    )
    A = -jnp.exp(P[p + "A_log"])
    s_bc = qc.scale_sym(sites[p + "bcdt"], 8)
    B_ = qc.fake_quant_sym(bcdt[..., r : r + n], s_bc)
    C_ = qc.fake_quant_sym(bcdt[..., r + n :], s_bc)
    y, _ = ref.selective_scan(xs, dt, A, B_, C_, P[p + "D"])
    gated = y * ref.silu(z)
    wq3, sw3 = ops[p + "out_proj.weight"]
    if mode == "quamba":
        s_yh = qc.scale_sym(sites[p + "gated_h"], 8)
        y8 = qc.quantize_sym(hu.fwht_jnp(gated), s_yh)
        return ref.matmul_i8(y8, wq3, float(s_yh), sw3)
    # LLM.int8-on-mamba: naive static y quantization — the `fail` row
    s_y = qc.scale_sym(sites[p + "gated"], 8)
    return ref.matmul_i8(qc.quantize_sym(gated, s_y), wq3, float(s_y), sw3)


def _moe_combo(cfg, P, p, h, mode, sites, ops):
    if mode == "fp":
        return _moe_block(cfg, P, p, h)
    gate = jax.nn.softmax(h @ P[p + "router"], axis=-1)
    kth = jnp.sort(gate, axis=-1)[..., -cfg.top_k : gate.shape[-1] - cfg.top_k + 1]
    mask = (gate >= kth).astype(gate.dtype)
    gate = gate * mask
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    s_in = qc.scale_sym(sites[p + "moe_in"], 8)
    out = 0.0
    for e in range(cfg.n_experts):
        parts = ops[p + f"expert{e}.w1.outliers"]
        hid = jax.nn.gelu(matmul_mixed(h, parts, float(s_in)) + P[p + f"expert{e}.b1"])
        out = out + gate[..., e : e + 1] * (hid @ P[p + f"expert{e}.w2"])
    return out


# Table 4 rows: (attn, mamba, moe)
TABLE4_COMBOS = [
    ("fp", "fp", "fp"),
    ("int8", "fp", "int8"),
    ("smq", "fp", "int8"),
    ("int8", "int8", "int8"),
    ("smq", "quamba", "int8"),
    ("int8", "quamba", "int8"),
]


def combo_name(c):
    names = {"fp": "FP16", "int8": "LLM.int8", "smq": "SmQ", "quamba": "Quamba"}
    return "+".join(names[m] for m in c)
