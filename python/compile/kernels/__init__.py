"""L1: Pallas kernels for the Quamba hot paths.

All kernels are lowered with ``interpret=True`` — the CPU PJRT plugin
cannot execute Mosaic custom-calls, so interpret mode is the supported
execution path on this testbed; real-TPU tiling/VMEM notes live in
DESIGN.md §7. Every kernel has a pure-jnp oracle in :mod:`.ref`.
"""

from . import ref  # noqa: F401
from .selective_scan import selective_scan_pallas, selective_scan_q_pallas  # noqa: F401
from .hadamard import hadamard_quant_pallas  # noqa: F401
from .causal_conv import causal_conv_silu_pallas, causal_conv_silu_q_pallas  # noqa: F401
from .rmsnorm import rmsnorm_resid_q_pallas  # noqa: F401
from .matmul_i8 import matmul_i8_pallas  # noqa: F401
