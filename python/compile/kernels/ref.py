"""Pure-jnp oracles for every Pallas kernel (the correctness ground
truth). Each function here is the mathematical definition; the Pallas
kernels in this package must match it to float tolerance under
``interpret=True`` — checked by ``python/tests/test_kernels.py`` with
hypothesis sweeps over shapes and dtypes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..quant import core as qc
from ..quant import hadamard_util as hu


def silu(x):
    return x * jax.nn.sigmoid(x)


def softplus(x):
    return jax.nn.softplus(x)


# ---------------------------------------------------------------------------
# Selective scan (paper Eq. 1, selective/discretized form)
# ---------------------------------------------------------------------------

def selective_scan(x, dt, A, B, C, D, h0=None):
    """Reference selective scan.

    x  : (Bb, T, Di)    SSM input
    dt : (Bb, T, Di)    softplus-ed time-scale Δ
    A  : (Di, N)        continuous state matrix (negative reals)
    B  : (Bb, T, N)     input-dependent input matrix
    C  : (Bb, T, N)     input-dependent output matrix
    D  : (Di,)          skip parameter
    h0 : (Bb, Di, N)    optional initial state

    Returns (y, hT): y (Bb, T, Di), hT (Bb, Di, N).
    Discretization: Ȧ = exp(Δ A), Ḃ = Δ B (paper §3.1 ZOH approx).
    """
    Bb, T, Di = x.shape
    N = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((Bb, Di, N), dtype=jnp.float32)

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp
        dA = jnp.exp(dt_t[:, :, None] * A[None, :, :])        # (Bb, Di, N)
        dB = dt_t[:, :, None] * B_t[:, None, :]               # (Bb, Di, N)
        h = dA * h + dB * x_t[:, :, None]
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    xs = (
        jnp.moveaxis(x, 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(B, 1, 0),
        jnp.moveaxis(C, 1, 0),
    )
    hT, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + x * D[None, None, :]
    return y, hT


def selective_scan_q(x_q, s_x, dt, A_q, s_A, B_q, s_B, C_q, s_C, D_q, s_D, h0=None):
    """Quantized selective scan oracle (paper §4.2): int8 weights (A, D)
    and activations (x, B, C) plus their static scales come in; the
    recurrence runs in f32 on dequantized values; y leaves in f32
    ("half precision" in the paper's GPU setting). Δ arrives already in
    f32 (it is produced by softplus of a quantized projection)."""
    x = qc.dequantize_sym(x_q, s_x)
    A = qc.dequantize_sym(A_q, s_A)
    B = qc.dequantize_sym(B_q, s_B)
    C = qc.dequantize_sym(C_q, s_C)
    D = qc.dequantize_sym(D_q, s_D)
    return selective_scan(x, dt, A, B, C, D, h0)


# ---------------------------------------------------------------------------
# Fused Hadamard quantize (paper §4.2, Eq. 3)
# ---------------------------------------------------------------------------

def hadamard_quant(y, s_y):
    """ȳ^H = clamp(round((H_n y) / s_y)) — the forward WHT with the
    quantization scale fused into the final butterfly stage."""
    yh = hu.fwht_jnp(y.astype(jnp.float32))
    return qc.quantize_sym(yh, s_y)


# ---------------------------------------------------------------------------
# Fused causal conv1d + SiLU + requant (paper §4.3)
# ---------------------------------------------------------------------------

def causal_conv_silu(x, w, bias):
    """Depthwise causal conv over time. x: (Bb, T, Di), w: (W, Di),
    bias: (Di,). Output f32 (Bb, T, Di): silu(conv(x) + b)."""
    W = w.shape[0]
    pads = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pads[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(W))
    return silu(out + bias[None, None, :])


def causal_conv_silu_q(x_q, s_x, w_q, s_w, bias, s_out, nbits=8, gain=None):
    """Quantized fused op: int8 in, int8 out. The int8×int8 products
    accumulate in i32; dequant by s_x*s_w; SiLU in f32; an optional
    per-channel gain (the outlier-injection diagonal, DESIGN.md §5)
    multiplies post-SiLU; requantize with the pre-calibrated s_out
    before the (simulated) write to memory."""
    W = w_q.shape[0]
    xp = jnp.pad(x_q.astype(jnp.int32), ((0, 0), (W - 1, 0), (0, 0)))
    acc = sum(xp[:, i : i + x_q.shape[1], :] * w_q[i].astype(jnp.int32)[None, None, :] for i in range(W))
    out = silu(acc.astype(jnp.float32) * (s_x * s_w) + bias[None, None, :])
    if gain is not None:
        out = out * gain[None, None, :]
    return qc.quantize_sym(out, s_out, nbits)


def causal_conv_step(x_t, conv_state, w, bias):
    """Single decode step of the causal conv. x_t: (Bb, Di),
    conv_state: (Bb, W-1, Di) holding the previous inputs.
    Returns (y_t, new_state)."""
    W = w.shape[0]
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (Bb, W, Di)
    out = jnp.einsum("bwd,wd->bd", window, w) + bias[None, :]
    return silu(out), window[:, 1:, :]


# ---------------------------------------------------------------------------
# Fused RMSNorm + residual + requant (paper §4.3)
# ---------------------------------------------------------------------------

def rmsnorm(x, weight, eps=1e-5):
    var = jnp.mean(x.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * weight


def rmsnorm_resid_q(x_out, x_res, weight, s_out, eps=1e-5, nbits=8):
    """(x̄_in^{L+1}, x_res^{L+1}) = (Q(RMSNorm(x_out + x_res)), x_out+x_res).
    Norm weights stay fp (paper: normalization in half precision)."""
    res = x_out + x_res
    return qc.quantize_sym(rmsnorm(res, weight, eps), s_out, nbits), res


# ---------------------------------------------------------------------------
# Int8 GEMM with i32 accumulation (paper §4.3 projection layers)
# ---------------------------------------------------------------------------

def matmul_i8(x_q, w_q, s_x, s_w, bias=None):
    """(.., K) i8 × (K, N) i8 → f32: i32 accumulate then dequantize.
    This is the CUTLASS-INT8-GEMM stand-in; on TPU it maps to the MXU
    i8 path."""
    acc = jax.lax.dot_general(
        x_q, w_q, (((x_q.ndim - 1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )
    out = acc.astype(jnp.float32) * (s_x * s_w)
    if bias is not None:
        out = out + bias
    return out
