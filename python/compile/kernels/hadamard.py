"""Pallas fused Hadamard-quantize kernel (paper §4.2, Eq. 3).

Computes ȳ^H = clamp(round((H_n y) / s_y)) over the channel dimension
with the quantization scale fused into the last butterfly stage, so the
transform+quantize is a single memory pass — the paper fuses 1/s_y into
the FWHT the same way. n = 2^p · m with m ∈ {1, 12, 20} (Paley base
matrices), covering every d_inner tier; the 2^p part is log₂ stages of
add/sub butterflies — no multiplies, ideal for the TPU VPU. The base-m
part is one small dense m×m contraction whose ±1 matrix is passed in
as a kernel operand (pallas kernels cannot capture traced constants).

Grid tiles rows (flattened batch·time); each step holds an (R_BLK, n)
tile in VMEM (R_BLK=8, n=320 → 10 KiB f32).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..quant import hadamard_util as hu

R_BLK = 8


def _make_kernel(n: int, p: int, m: int, s_y: float, nbits: int):
    qmax = 2 ** (nbits - 1) - 1
    qmin = -(2 ** (nbits - 1))
    inv_s = 1.0 / float(s_y)

    def kernel(y_ref, hm_ref, o_ref):
        y = y_ref[...].astype(jnp.float32)          # (R, n)
        r = y.shape[0]
        if m > 1:
            hm = hm_ref[...]
            y = (y.reshape(r, 2**p, m) @ hm.T).reshape(r, n)
        h = 1
        while h < 2**p:
            y = y.reshape(r, (2**p) // (2 * h), 2, h * m)
            a = y[:, :, 0, :]
            b = y[:, :, 1, :]
            y = jnp.stack([a + b, a - b], axis=2).reshape(r, n)
            h *= 2
        # final stage: fuse the 1/s_y scaling and the int8 clamp/round
        q = jnp.clip(jnp.round(y * inv_s), qmin, qmax)
        o_ref[...] = q.astype(jnp.int8)

    return kernel


def hadamard_quant_pallas(y, s_y, nbits: int = 8):
    """y: (..., n) f32 → int8 (..., n). Matches ref.hadamard_quant."""
    shape = y.shape
    n = shape[-1]
    p, m = hu.decompose(n)
    rows = 1
    for d in shape[:-1]:
        rows *= d
    rb = R_BLK if rows % R_BLK == 0 else 1
    y2 = y.reshape(rows, n)
    # base matrix operand (H_1 dummy when n is a pure power of two)
    hm = jnp.asarray(hu.hadamard(m) if m > 1 else np.eye(1), dtype=jnp.float32)
    mm = hm.shape[0]
    out = pl.pallas_call(
        _make_kernel(n, p, m, float(s_y), nbits),
        grid=(rows // rb,),
        in_specs=[
            pl.BlockSpec((rb, n), lambda r: (r, 0)),
            pl.BlockSpec((mm, mm), lambda r: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rb, n), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, n), jnp.int8),
        interpret=True,
    )(y2, hm)
    return out.reshape(shape)
