"""Pallas int8 GEMM with i32 accumulation (paper §4.3 projections).

The CUTLASS-INT8-tensor-core stand-in: x̄ (M,K) i8 · W̄ (K,N) i8 →
i32 accumulate → dequantize by s_x·s_w → f32 (+bias). On TPU this
contraction maps onto the MXU's native 8-bit path (DESIGN.md §7); the
dequant multiply fuses into the MXU drain.

Grid tiles the N dimension (bn = 64 when it divides N, else one
block); M and K stay whole — our tiers keep M·K ≤ 2048·640 i8 ≈
1.3 MiB, within a double-buffered VMEM budget. MXU-utilization
estimate per tier is recorded in DESIGN.md §9.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BN = 64


def _pick_bn(n: int) -> int:
    for bn in (BN, 32, 16, 8):
        if n % bn == 0:
            return bn
    return n


def _make_kernel(s: float, has_bias: bool):
    def kernel(*refs):
        if has_bias:
            x_ref, w_ref, b_ref, o_ref = refs
        else:
            x_ref, w_ref, o_ref = refs
        acc = jax.lax.dot_general(
            x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        out = acc.astype(jnp.float32) * s
        if has_bias:
            out = out + b_ref[...][None, :]
        o_ref[...] = out

    return kernel


def matmul_i8_pallas(x_q, w_q, s_x, s_w, bias=None):
    """x_q (..., K) i8 × w_q (K, N) i8 → f32 (..., N). Static scales.
    Matches ref.matmul_i8."""
    shape = x_q.shape
    K = shape[-1]
    N = w_q.shape[1]
    rows = 1
    for d in shape[:-1]:
        rows *= d
    bn = _pick_bn(N)
    x2 = x_q.reshape(rows, K)
    s = float(s_x) * float(s_w)
    in_specs = [
        pl.BlockSpec((rows, K), lambda n: (0, 0)),
        pl.BlockSpec((K, bn), lambda n: (0, n)),
    ]
    args = [x2, w_q]
    if bias is not None:
        in_specs.append(pl.BlockSpec((bn,), lambda n: (n,)))
        args.append(bias)
    out = pl.pallas_call(
        _make_kernel(s, bias is not None),
        grid=(N // bn,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((rows, bn), lambda n: (0, n)),
        out_shape=jax.ShapeDtypeStruct((rows, N), jnp.float32),
        interpret=True,
    )(*args)
    return out.reshape(shape[:-1] + (N,))
