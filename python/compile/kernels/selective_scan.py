"""Pallas selective-scan kernels (the paper's compute hot-spot).

TPU adaptation of the CUDA selective-scan kernel (DESIGN.md §7): the
grid tiles (batch × channel-blocks); each grid step holds an
(x-block, Δ-block, B, C, h-carry) working set in VMEM and walks the
time dimension with a fori loop, exactly where the CUDA kernel walked
it with a threadblock-resident state. The quantized variant takes int8
activations/weights plus their *static* scales (baked as compile-time
constants — per-tensor symmetric, paper §4.2) and runs the recurrence
in f32, emitting f32 y ("half" on the paper's GPUs).

Block size: BD channels per grid step. VMEM working set per step
(prefill, T time steps, N states):
    x, Δ, y blocks : 3 · T·BD·4  B
    B, C blocks    : 2 · T·N·4   B
    h carry        : BD·N·4      B
For T=256, BD=32, N=16: ≈ 130 KiB — comfortably double-bufferable in
a 16 MiB VMEM; the MXU is not used here (the scan is elementwise +
small contractions), so this kernel is VPU-bound, matching the
memory-bound character of the CUDA original.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BD = 32


def _pick_bd(di: int) -> int:
    for bd in (DEFAULT_BD, 16, 8, 4, 2, 1):
        if di % bd == 0:
            return bd
    return 1


def _make_kernel(T: int, N: int, BD: int, quant: bool, scales):
    """Build the kernel body. When `quant`, int8 refs are dequantized
    with the static `scales` dict (python floats, compile-time)."""

    def kernel(x_ref, dt_ref, B_ref, C_ref, A_ref, D_ref, h0_ref, y_ref, hT_ref):
        if quant:
            A = A_ref[...].astype(jnp.float32) * scales["A"]     # (BD, N)
            D = D_ref[...].astype(jnp.float32) * scales["D"]     # (BD,)
        else:
            A = A_ref[...]
            D = D_ref[...]
        h0 = h0_ref[0]                                            # (BD, N)

        def step(t, h):
            x_t = x_ref[0, pl.dslice(t, 1), :][0]    # (BD,)
            dt_t = dt_ref[0, pl.dslice(t, 1), :][0]
            B_t = B_ref[0, pl.dslice(t, 1), :][0]    # (N,)
            C_t = C_ref[0, pl.dslice(t, 1), :][0]
            if quant:
                x_t = x_t.astype(jnp.float32) * scales["x"]
                B_t = B_t.astype(jnp.float32) * scales["B"]
                C_t = C_t.astype(jnp.float32) * scales["C"]
            dA = jnp.exp(dt_t[:, None] * A)                       # (BD, N)
            h = dA * h + (dt_t * x_t)[:, None] * B_t[None, :]
            y_t = h @ C_t + D * x_t                               # (BD,)
            y_ref[0, pl.dslice(t, 1), :] = y_t[None, :]
            return h

        hT = jax.lax.fori_loop(0, T, step, h0)
        hT_ref[0] = hT

    return kernel


def _call(x, dt, A, B, C, D, h0, quant: bool, scales=None):
    Bb, T, Di = x.shape
    N = A.shape[1]
    BD = _pick_bd(Di)
    grid = (Bb, Di // BD)
    kernel = _make_kernel(T, N, BD, quant, scales)
    y, hT = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, T, BD), lambda b, d: (b, 0, d)),   # x
            pl.BlockSpec((1, T, BD), lambda b, d: (b, 0, d)),   # dt
            pl.BlockSpec((1, T, N), lambda b, d: (b, 0, 0)),    # B
            pl.BlockSpec((1, T, N), lambda b, d: (b, 0, 0)),    # C
            pl.BlockSpec((BD, N), lambda b, d: (d, 0)),         # A
            pl.BlockSpec((BD,), lambda b, d: (d,)),             # D
            pl.BlockSpec((1, BD, N), lambda b, d: (b, d, 0)),   # h0
        ],
        out_specs=[
            pl.BlockSpec((1, T, BD), lambda b, d: (b, 0, d)),   # y
            pl.BlockSpec((1, BD, N), lambda b, d: (b, d, 0)),   # hT
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, T, Di), jnp.float32),
            jax.ShapeDtypeStruct((Bb, Di, N), jnp.float32),
        ],
        interpret=True,
    )(x, dt, B, C, A, D, h0)
    return y, hT


def selective_scan_pallas(x, dt, A, B, C, D, h0=None):
    """fp32 Pallas selective scan; matches ref.selective_scan."""
    Bb, T, Di = x.shape
    N = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((Bb, Di, N), dtype=jnp.float32)
    # the D·x skip connection is computed inside the kernel
    return _call(x, dt, A, B, C, D, h0, quant=False)


def selective_scan_q_pallas(x_q, s_x, dt, A_q, s_A, B_q, s_B, C_q, s_C, D_q, s_D, h0=None):
    """Quantized Pallas selective scan; matches ref.selective_scan_q.
    Scales are python floats — they are baked into the lowered HLO as
    constants (the paper's *static* quantization; zero runtime scale
    traffic)."""
    Bb, T, Di = x_q.shape
    N = A_q.shape[1]
    if h0 is None:
        h0 = jnp.zeros((Bb, Di, N), dtype=jnp.float32)
    scales = {"x": float(s_x), "A": float(s_A), "B": float(s_B), "C": float(s_C), "D": float(s_D)}
    y, hT = _call(x_q, dt, A_q, B_q, C_q, D_q, h0, quant=True, scales=scales)
    return y, hT
