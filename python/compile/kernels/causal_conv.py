"""Pallas fused causal conv1d + SiLU + requantize (paper §4.3).

Depthwise causal convolution of width W over time with the SiLU and the
output quantization fused before the write — the operator is
memory-bound (as the paper notes, citing depthwise-conv studies), so
int8 I/O halves its memory traffic and the fusion removes two extra
memory passes.

Grid tiles (batch × channel-blocks); each step loads a (T, BD) slab,
does W shift-multiplies in registers (W=4), applies SiLU and the static
requant scale. VMEM per step ≈ (T·BD)·(1B in + 4B fp + 1B out).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BD = 32


def _pick_bd(di: int) -> int:
    for bd in (BD, 16, 8, 4, 2, 1):
        if di % bd == 0:
            return bd
    return 1


def _silu(x):
    return x * jax.nn.sigmoid(x)


def _make_kernel_fp(W: int):
    def kernel(x_ref, w_ref, b_ref, o_ref):
        x = x_ref[0]                       # (T, BD) f32
        w = w_ref[...]                     # (W, BD)
        b = b_ref[...]                     # (BD,)
        T = x.shape[0]
        acc = jnp.zeros_like(x)
        for i in range(W):
            # x[t - (W-1) + i]: shift x down by (W-1-i) rows, zero-fill
            shift = W - 1 - i
            shifted = jnp.pad(x, ((shift, 0), (0, 0)))[:T]
            acc = acc + shifted * w[i][None, :]
        o_ref[0] = _silu(acc + b[None, :])

    return kernel


def _make_kernel_q(W: int, s_x: float, s_w: float, s_out: float, nbits: int):
    qmax = 2 ** (nbits - 1) - 1
    qmin = -(2 ** (nbits - 1))
    s_in = float(s_x) * float(s_w)
    inv_out = 1.0 / float(s_out)

    def kernel(x_ref, w_ref, b_ref, g_ref, o_ref):
        x = x_ref[0].astype(jnp.int32)     # (T, BD) i8 -> i32
        w = w_ref[...].astype(jnp.int32)   # (W, BD)
        b = b_ref[...]                     # (BD,) f32
        g = g_ref[...]                     # (BD,) f32 post-SiLU gain
        T = x.shape[0]
        acc = jnp.zeros(x.shape, jnp.int32)
        for i in range(W):
            shift = W - 1 - i
            shifted = jnp.pad(x, ((shift, 0), (0, 0)))[:T]
            acc = acc + shifted * w[i][None, :]
        out = _silu(acc.astype(jnp.float32) * s_in + b[None, :]) * g[None, :]
        q = jnp.clip(jnp.round(out * inv_out), qmin, qmax)
        o_ref[0] = q.astype(jnp.int8)

    return kernel


def causal_conv_silu_pallas(x, w, bias):
    """fp32 variant: x (Bb,T,Di), w (W,Di), bias (Di,) → f32 (Bb,T,Di)."""
    Bb, T, Di = x.shape
    W = w.shape[0]
    bd = _pick_bd(Di)
    return pl.pallas_call(
        _make_kernel_fp(W),
        grid=(Bb, Di // bd),
        in_specs=[
            pl.BlockSpec((1, T, bd), lambda b, d: (b, 0, d)),
            pl.BlockSpec((W, bd), lambda b, d: (0, d)),
            pl.BlockSpec((bd,), lambda b, d: (d,)),
        ],
        out_specs=pl.BlockSpec((1, T, bd), lambda b, d: (b, 0, d)),
        out_shape=jax.ShapeDtypeStruct((Bb, T, Di), jnp.float32),
        interpret=True,
    )(x, w, bias)


def causal_conv_silu_q_pallas(x_q, s_x, w_q, s_w, bias, s_out, nbits: int = 8, gain=None):
    """Quantized variant: int8 in/out; matches ref.causal_conv_silu_q.
    `gain` is the optional per-channel post-SiLU diagonal (outlier
    injection, DESIGN.md §5); identity when None."""
    Bb, T, Di = x_q.shape
    W = w_q.shape[0]
    bd = _pick_bd(Di)
    if gain is None:
        gain = jnp.ones((Di,), jnp.float32)
    return pl.pallas_call(
        _make_kernel_q(W, s_x, s_w, s_out, nbits),
        grid=(Bb, Di // bd),
        in_specs=[
            pl.BlockSpec((1, T, bd), lambda b, d: (b, 0, d)),
            pl.BlockSpec((W, bd), lambda b, d: (0, d)),
            pl.BlockSpec((bd,), lambda b, d: (d,)),
            pl.BlockSpec((bd,), lambda b, d: (d,)),
        ],
        out_specs=pl.BlockSpec((1, T, bd), lambda b, d: (b, 0, d)),
        out_shape=jax.ShapeDtypeStruct((Bb, T, Di), jnp.int8),
        interpret=True,
    )(x_q, w_q, bias, gain)
