"""Pallas fused RMSNorm + residual-add + static requantize (paper §4.3).

Takes the half-precision tuple (x_out, x_res) from the previous Quamba
block, returns (x̄_in int8 for the next block, new residual in fp). The
norm weight stays fp (the paper does not quantize normalization
weights). One memory pass: load both inputs, write both outputs.

Grid tiles rows; the whole d_model fits one block (<= 160 channels).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

R_BLK = 8


def _make_kernel(s_out: float, eps: float, nbits: int):
    qmax = 2 ** (nbits - 1) - 1
    qmin = -(2 ** (nbits - 1))
    inv = 1.0 / float(s_out)

    def kernel(xo_ref, xr_ref, w_ref, q_ref, res_ref):
        xo = xo_ref[...].astype(jnp.float32)   # (R, D)
        xr = xr_ref[...].astype(jnp.float32)
        w = w_ref[...]
        res = xo + xr
        var = jnp.mean(res * res, axis=-1, keepdims=True)
        normed = res * jax.lax.rsqrt(var + eps) * w[None, :]
        q_ref[...] = jnp.clip(jnp.round(normed * inv), qmin, qmax).astype(jnp.int8)
        res_ref[...] = res

    return kernel


def rmsnorm_resid_q_pallas(x_out, x_res, weight, s_out, eps: float = 1e-5, nbits: int = 8):
    """Matches ref.rmsnorm_resid_q; shapes (..., D)."""
    shape = x_out.shape
    D = shape[-1]
    rows = 1
    for d in shape[:-1]:
        rows *= d
    rb = R_BLK if rows % R_BLK == 0 else 1
    q, res = pl.pallas_call(
        _make_kernel(float(s_out), eps, nbits),
        grid=(rows // rb,),
        in_specs=[
            pl.BlockSpec((rb, D), lambda r: (r, 0)),
            pl.BlockSpec((rb, D), lambda r: (r, 0)),
            pl.BlockSpec((D,), lambda r: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((rb, D), lambda r: (r, 0)),
            pl.BlockSpec((rb, D), lambda r: (r, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, D), jnp.int8),
            jax.ShapeDtypeStruct((rows, D), jnp.float32),
        ],
        interpret=True,
    )(x_out.reshape(rows, D), x_res.reshape(rows, D), weight)
    return q.reshape(shape), res.reshape(shape)
