"""Tiny from-scratch trainer (build path only).

Produces real (non-random) weights for every Mamba tier and the
Transformer baseline on the synthetic Markov-English corpus, so the
quantization experiments measure degradation of an actual language
model rather than noise. Hand-rolled AdamW (optax is not available in
the offline environment). A few hundred steps per tier is enough: the
models reach well-below-unigram perplexity and develop the smooth /
peaked activation statistics calibration needs.
"""

from __future__ import annotations

import time
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import model as model_mod
from . import transformer as tr_mod


def cross_entropy(logits, targets):
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return nll.mean()


def adamw_init(params):
    return {
        "m": {k: jnp.zeros_like(v) for k, v in params.items()},
        "v": {k: jnp.zeros_like(v) for k, v in params.items()},
        "t": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, state, lr=3e-3, b1=0.9, b2=0.95, eps=1e-8, wd=0.01):
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * grads[k] ** 2 for k in params}
    bc1 = 1 - b1**t.astype(jnp.float32)
    bc2 = 1 - b2**t.astype(jnp.float32)
    new_params = {}
    for k in params:
        mh = m[k] / bc1
        vh = v[k] / bc2
        upd = mh / (jnp.sqrt(vh) + eps)
        if params[k].ndim >= 2:          # decoupled decay on matrices only
            upd = upd + wd * params[k]
        new_params[k] = params[k] - lr * upd
    return new_params, {"m": m, "v": v, "t": t}


def train_mamba(cfg, stream, steps=300, batch=8, seqlen=128, lr=3e-3, seed=0, log_every=50,
                quiet=False, gains=None):
    params = {k: jnp.asarray(v) for k, v in model_mod.init_params(cfg, seed).items()}
    opt = adamw_init(params)
    gains_j = None if gains is None else (jnp.asarray(gains.g_x), jnp.asarray(gains.g_y))

    def loss_fn(p, x, y):
        logits, _, _ = model_mod.forward_fp(cfg, p, x, gains=gains_j)
        return cross_entropy(logits, y)

    @jax.jit
    def step_fn(p, o, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(p, x, y)
        p, o = adamw_update(p, grads, o, lr=lr)
        return p, o, loss

    gen = data_mod.batches(stream, batch, seqlen, seed=seed + 1)
    losses = []
    t0 = time.time()
    for it in range(steps):
        x, y = next(gen)
        params, opt, loss = step_fn(params, opt, jnp.asarray(x), jnp.asarray(y))
        losses.append(float(loss))
        if not quiet and (it % log_every == 0 or it == steps - 1):
            print(f"  [{cfg.name}] step {it:4d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.1f}s)", flush=True)
    return OrderedDict((k, np.asarray(v)) for k, v in params.items()), losses


def train_transformer(cfg, stream, steps=300, batch=8, seqlen=128, lr=3e-3, seed=1,
                      log_every=50, quiet=False):
    params = {k: jnp.asarray(v) for k, v in tr_mod.init_params(cfg, seed).items()}
    opt = adamw_init(params)
    # train with a compact cache sized to the training seqlen (the
    # forward allocates (L,B,max_ctx,...); full 2048 would waste steps)
    train_cfg = tr_mod.TransformerTier(
        name=cfg.name, paper_name=cfg.paper_name, d_model=cfg.d_model,
        n_layer=cfg.n_layer, n_head=cfg.n_head, max_ctx=seqlen, vocab=cfg.vocab)

    def loss_fn(p, x, y):
        logits, _, _ = tr_mod.forward_fp(train_cfg, p, x)
        return cross_entropy(logits, y)

    @jax.jit
    def step_fn(p, o, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(p, x, y)
        p, o = adamw_update(p, grads, o, lr=lr)
        return p, o, loss

    gen = data_mod.batches(stream, batch, seqlen, seed=seed + 1)
    losses = []
    t0 = time.time()
    for it in range(steps):
        x, y = next(gen)
        params, opt, loss = step_fn(params, opt, jnp.asarray(x), jnp.asarray(y))
        losses.append(float(loss))
        if not quiet and (it % log_every == 0 or it == steps - 1):
            print(f"  [{cfg.name}] step {it:4d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.1f}s)", flush=True)
    return OrderedDict((k, np.asarray(v)) for k, v in params.items()), losses
