"""AOT artifact builder: corpus → train → calibrate → quantize → lower.

Emits everything the rust runtime consumes (HLO **text** — jax ≥0.5
serialized protos carry 64-bit instruction ids that xla_extension 0.5.1
rejects; the text parser reassigns ids):

    artifacts/
      manifest.json                      the artifact index (rust reads it)
      graphs/{tier}_{method}_prefill_b{B}_t{T}.hlo.txt
      graphs/{tier}_{method}_decode_b{B}.hlo.txt
      graphs/{ttier}_{method}_...        transformer baseline graphs
      weights/{tier}_{method}.qtz        runtime weight parameters
      data/pile_eval.qtz  wiki_eval.qtz  calib.qtz   token streams
      data/tasks.json                    six-task zero-shot suite
      train_cache/{tier}.qtz             trained fp weights (reused)

Python runs once; `make artifacts` is a no-op when inputs are
unchanged. Nothing here is on the request path.
"""

from __future__ import annotations

import argparse
import json
import os
import time
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as data_mod
from . import model as model_mod
from . import outliers as outliers_mod
from . import qtz
from . import train as train_mod
from . import transformer as tr_mod
from .quant import calibrate as cal_mod
from .quant import config as qconf

TRAIN_STEPS = {"m130": 260, "m370": 230, "m1p4": 210, "m2p8": 220}
T_TRAIN_STEPS = {"p2p8": 150}
PREFILL_T = 256
LONG_T = (512, 1024, 2048)
LONG_T_METHODS = ("fp16", "quamba", "smoothquant", "quarot", "w8a8_static")
DECODE_BATCHES_WIDE = (2, 4, 8)


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------

def to_hlo_text(fn, example_args) -> str:
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # Two sharp edges of the HLO-text interchange (DESIGN.md §2):
    # 1. jax DCE may DROP unused parameters from the entry signature;
    #    the rust runtime feeds every manifest weight, so the counts
    #    must match exactly — fail the build here, not at serve time.
    n_params = len(comp.program_shape().parameter_shapes())
    if n_params != len(example_args):
        raise RuntimeError(
            f"graph lost parameters in lowering: {n_params} != {len(example_args)} "
            "(an unused weight was DCE'd; keep every weight on the used path)"
        )
    # 2. print_large_constants=True is LOAD-BEARING: the default printer
    #    elides big constant payloads as `constant({...})`, which the
    #    xla_extension 0.5.1 text parser silently mis-reads — every
    #    baked constant (outlier gains, Hadamard bases, Jamba combo
    #    weights) would be corrupted on the rust side.
    return comp.as_hlo_text(print_large_constants=True)


def _sds(arr):
    return jax.ShapeDtypeStruct(np.asarray(arr).shape, np.asarray(arr).dtype)


# ---------------------------------------------------------------------------
# Graph constructors (close over baked scales & gains; weights runtime)
# ---------------------------------------------------------------------------

def mamba_graph_fn(cfg, method, qa, weight_names, gains, fresh_state):
    """Returns f(tokens, conv, ssm, *weights) -> (logits, conv', ssm')."""
    gains_j = None if gains is None else (jnp.asarray(gains.g_x), jnp.asarray(gains.g_y))

    if method.is_fp:
        def fn(tokens, conv, ssm, *weights):
            params = dict(zip(weight_names, weights))
            return model_mod.forward_fp(cfg, params, tokens, conv, ssm, gains=gains_j)
        return fn
    if method.weight_only:
        def fn(tokens, conv, ssm, *weights):
            w = dict(zip(weight_names, weights))
            return model_mod.forward_weight_only(cfg, qa, w, tokens, conv, ssm, gains=gains_j)
        return fn

    def fn(tokens, conv, ssm, *weights):
        w = dict(zip(weight_names, weights))
        return model_mod.forward_q(cfg, qa, w, tokens, conv, ssm,
                                   use_pallas=True, fresh_state=fresh_state, gains=gains_j)
    return fn


def transformer_graph_fn(cfg, method, wscales, ascales, weight_names):
    if method == "fp16":
        def fn(tokens, k_cache, v_cache, cache_len, *weights):
            p = dict(zip(weight_names, weights))
            return tr_mod.forward_fp(cfg, p, tokens, k_cache, v_cache, cache_len)
        return fn

    def fn(tokens, k_cache, v_cache, cache_len, *weights):
        wq = dict(zip(weight_names, weights))
        return tr_mod.forward_q(cfg, method, None, wq, wscales, ascales, tokens,
                                k_cache, v_cache, cache_len)
    return fn


# ---------------------------------------------------------------------------
# Builder
# ---------------------------------------------------------------------------

class Builder:
    def __init__(self, out_dir: str, quick: bool = False, verbose: bool = True):
        self.out = out_dir
        self.quick = quick
        self.verbose = verbose
        self.manifest = {
            "version": 1,
            "built_at": time.strftime("%Y-%m-%d %H:%M:%S"),
            "quick": quick,
            "vocab_size": data_mod.VOCAB_SIZE,
            "graphs": {},
            "weights": {},
            "tiers": {},
            "transformer_tiers": {},
            "data": {},
            "methods": sorted(qconf.METHODS.keys()),
        }
        for sub in ("graphs", "weights", "data", "train_cache"):
            os.makedirs(os.path.join(out_dir, sub), exist_ok=True)
        # incremental builds: merge into an existing manifest so partial
        # rebuilds (--tiers / --methods) do not clobber earlier entries
        prev = os.path.join(out_dir, "manifest.json")
        if os.path.exists(prev):
            try:
                with open(prev) as f:
                    old = json.load(f)
                if old.get("quick") == quick:
                    for k in ("graphs", "weights", "tiers", "transformer_tiers", "data"):
                        merged = dict(old.get(k, {}))
                        merged.update(self.manifest[k])
                        self.manifest[k] = merged
            except (json.JSONDecodeError, OSError):
                pass

    def log(self, *a):
        if self.verbose:
            print("[aot]", *a, flush=True)

    # -- data -----------------------------------------------------------------
    def build_data(self):
        self.log("building corpora + task suite")
        pile, wiki = data_mod.make_corpora()
        n_train = 40_000 if self.quick else 220_000
        n_eval = 4_000 if self.quick else 24_000
        self.train_stream = data_mod.token_stream(pile, n_train, seed=1)
        pile_eval = data_mod.token_stream(pile, n_eval, seed=2)
        wiki_eval = data_mod.token_stream(wiki, n_eval, seed=3)
        qtz.save(self._p("data/calib.qtz"), {"tokens": self.train_stream[:n_eval]})
        qtz.save(self._p("data/pile_eval.qtz"), {"tokens": pile_eval})
        qtz.save(self._p("data/wiki_eval.qtz"), {"tokens": wiki_eval})
        n_ex = 24 if self.quick else 120
        suite = data_mod.build_task_suite(pile, n_ex=n_ex)
        with open(self._p("data/tasks.json"), "w") as f:
            json.dump(suite, f, default=int)
        with open(self._p("data/vocab.json"), "w") as f:
            f.write(data_mod.Vocab().to_json())
        self.manifest["data"] = {
            "calib": "data/calib.qtz",
            "pile_eval": "data/pile_eval.qtz",
            "wiki_eval": "data/wiki_eval.qtz",
            "tasks": "data/tasks.json",
            "vocab": "data/vocab.json",
        }

    # -- training (cached) ------------------------------------------------------
    def trained_params(self, cfg, tier_index):
        gains = outliers_mod.OutlierSpec.for_tier(cfg, tier_index)
        cache = self._p(f"train_cache/{cfg.name}.qtz")
        steps = 30 if self.quick else TRAIN_STEPS[cfg.name]
        key = f"{cfg.name}-{steps}-{cfg.d_model}-{cfg.n_layer}"
        if os.path.exists(cache):
            t = qtz.load(cache)
            if "__key" in t and bytes(t["__key"]).decode() == key:
                self.log(f"{cfg.name}: using cached weights")
                t.pop("__key")
                return OrderedDict(t), gains
        self.log(f"{cfg.name}: training {steps} steps "
                 f"({cfg.n_params()/1e6:.2f}M params)")
        params, _ = train_mod.train_mamba(
            cfg, self.train_stream, steps=steps, quiet=not self.verbose, gains=gains)
        params = outliers_mod.inject_conv_in(cfg, params)
        save = OrderedDict(params)
        save["__key"] = np.frombuffer(key.encode(), dtype=np.uint8).copy()
        qtz.save(cache, save)
        return params, gains

    # -- one (tier, methods) bundle ----------------------------------------------
    def build_mamba_tier(self, cfg, tier_index, methods):
        params, gains = self.trained_params(cfg, tier_index)
        self.log(f"{cfg.name}: calibrating")
        stats = cal_mod.calibrate(
            cfg, params, self.train_stream,
            n_samples=16 if self.quick else 64,
            seqlen=64 if self.quick else 256,
            batch=8, gains=gains)
        self.manifest["tiers"][cfg.name] = {
            "paper_name": cfg.paper_name,
            "d_model": cfg.d_model, "n_layer": cfg.n_layer,
            "d_state": cfg.d_state, "d_conv": cfg.d_conv,
            "d_inner": cfg.d_inner, "dt_rank": cfg.dt_rank,
            "vocab": cfg.vocab, "n_params": cfg.n_params(),
            "outliers": gains.stats(),
        }
        T = 64 if self.quick else PREFILL_T
        for mname in methods:
            method = qconf.METHODS[mname]
            t0 = time.time()
            if method.is_fp:
                weights = OrderedDict((k, np.asarray(v, np.float32)) for k, v in params.items())
                qa = None
            else:
                qa = cal_mod.build_artifacts(cfg, params, method, stats)
                weights = qa.weights
            wfile = f"weights/{cfg.name}_{mname}.qtz"
            wnames = list(weights.keys())
            # the gains are baked into the graphs as constants; ship them
            # in the qtz too (outside the graph-param list) so the rust
            # reference simulator can reproduce the same model
            save_w = OrderedDict(weights)
            save_w["__gains.g_x"] = gains.g_x
            save_w["__gains.g_y"] = gains.g_y
            qtz.save(self._p(wfile), save_w)
            self.manifest["weights"][f"{cfg.name}_{mname}"] = {
                "file": wfile, "params": wnames,
                "bytes": int(sum(np.asarray(v).nbytes for v in weights.values())),
            }
            # (1, T): latency reference; (4, T): perplexity windows;
            # (8, T_task): zero-shot task scoring
            T_task = 32 if self.quick else 64
            batches_T = [(1, T), (4, T), (8, T_task)]
            if cfg.name == "m2p8" and not self.quick and mname in LONG_T_METHODS:
                batches_T += [(1, t) for t in LONG_T]
            decode_bs = [1]
            if cfg.name == "m2p8" and not self.quick and mname in ("fp16", "quamba"):
                decode_bs += list(DECODE_BATCHES_WIDE)
            for (B, t_len) in batches_T:
                self._lower_mamba(cfg, method, qa, weights, wnames, gains, B, t_len, "prefill")
            for B in decode_bs:
                self._lower_mamba(cfg, method, qa, weights, wnames, gains, B, 1, "decode")
            self.log(f"{cfg.name}/{mname}: lowered in {time.time()-t0:.1f}s")

    def _lower_mamba(self, cfg, method, qa, weights, wnames, gains, B, T, kind):
        fresh = kind == "prefill"
        fn = mamba_graph_fn(cfg, method, qa, wnames, gains, fresh_state=fresh)
        tokens = jax.ShapeDtypeStruct((B, T), np.int32)
        conv = jax.ShapeDtypeStruct((cfg.n_layer, B, cfg.d_conv - 1, cfg.d_inner), np.float32)
        ssm = jax.ShapeDtypeStruct((cfg.n_layer, B, cfg.d_inner, cfg.d_state), np.float32)
        args = [tokens, conv, ssm] + [_sds(weights[n]) for n in wnames]
        text = to_hlo_text(fn, args)
        name = (f"{cfg.name}_{method.name}_prefill_b{B}_t{T}" if kind == "prefill"
                else f"{cfg.name}_{method.name}_decode_b{B}")
        gfile = f"graphs/{name}.hlo.txt"
        with open(self._p(gfile), "w") as f:
            f.write(text)
        self.manifest["graphs"][name] = {
            "file": gfile,
            "family": "mamba",
            "tier": cfg.name,
            "method": method.name,
            "kind": kind,
            "batch": B,
            "seq": T,
            "weights": f"{cfg.name}_{method.name}",
            "inputs": ["tokens:i32", "conv_state:f32", "ssm_state:f32"] + wnames,
            "outputs": ["logits:f32", "conv_state:f32", "ssm_state:f32"],
        }

    # -- transformer baseline -------------------------------------------------
    def build_transformer(self, cfg, methods=("fp16", "w8a8_static", "smoothquant")):
        cache = self._p(f"train_cache/{cfg.name}.qtz")
        steps = 30 if self.quick else T_TRAIN_STEPS.get(cfg.name, 150)
        if os.path.exists(cache):
            params = OrderedDict(qtz.load(cache))
            self.log(f"{cfg.name}: using cached weights")
        else:
            self.log(f"{cfg.name}: training transformer {steps} steps "
                     f"({cfg.n_params()/1e6:.2f}M params)")
            params, _ = train_mod.train_transformer(
                cfg, self.train_stream, steps=steps, quiet=not self.verbose)
            qtz.save(cache, params)
        self.manifest["transformer_tiers"][cfg.name] = {
            "paper_name": cfg.paper_name,
            "d_model": cfg.d_model, "n_layer": cfg.n_layer, "n_head": cfg.n_head,
            "max_ctx": cfg.max_ctx, "vocab": cfg.vocab, "n_params": cfg.n_params(),
        }
        T = 64 if self.quick else PREFILL_T
        for mname in methods:
            if mname == "fp16":
                weights = OrderedDict((k, np.asarray(v, np.float32)) for k, v in params.items())
                wsc, asc = {}, {}
            else:
                alpha = 0.5 if mname == "smoothquant" else None
                wq, wsc, asc = tr_mod.calibrate_and_quantize(
                    cfg, params, self.train_stream, mname, smooth_alpha=alpha)
                weights = wq
            wfile = f"weights/{cfg.name}_{mname}.qtz"
            qtz.save(self._p(wfile), weights)
            wnames = list(weights.keys())
            self.manifest["weights"][f"{cfg.name}_{mname}"] = {
                "file": wfile, "params": wnames,
                "bytes": int(sum(np.asarray(v).nbytes for v in weights.values())),
            }
            t_lens = [T] + (list(LONG_T) if (not self.quick and mname == "fp16") else [])
            for t_len in t_lens:
                self._lower_transformer(cfg, mname, weights, wnames, wsc, asc, 1, t_len, "prefill")
            self._lower_transformer(cfg, mname, weights, wnames, wsc, asc, 1, 1, "decode")

    def _lower_transformer(self, cfg, mname, weights, wnames, wsc, asc, B, T, kind):
        fn = transformer_graph_fn(cfg, mname, wsc, asc, wnames)
        tokens = jax.ShapeDtypeStruct((B, T), np.int32)
        kc = jax.ShapeDtypeStruct((cfg.n_layer, B, cfg.max_ctx, cfg.n_head, cfg.d_head),
                                  np.float32)
        cache_len = jax.ShapeDtypeStruct((), np.int32)
        args = [tokens, kc, kc, cache_len] + [_sds(weights[n]) for n in wnames]
        text = to_hlo_text(fn, args)
        name = (f"{cfg.name}_{mname}_prefill_b{B}_t{T}" if kind == "prefill"
                else f"{cfg.name}_{mname}_decode_b{B}")
        gfile = f"graphs/{name}.hlo.txt"
        with open(self._p(gfile), "w") as f:
            f.write(text)
        self.manifest["graphs"][name] = {
            "file": gfile,
            "family": "transformer",
            "tier": cfg.name,
            "method": mname,
            "kind": kind,
            "batch": B,
            "seq": T,
            "weights": f"{cfg.name}_{mname}",
            "inputs": ["tokens:i32", "k_cache:f32", "v_cache:f32", "cache_len:i32"] + wnames,
            "outputs": ["logits:f32", "k_cache:f32", "v_cache:f32"],
        }

    # -- Jamba hybrid (Table 4) -------------------------------------------------
    def build_jamba(self):
        from . import jamba as jm

        cfg = jm.JAMBA_TIER
        cache = self._p("train_cache/jamba.qtz")
        steps = 20 if self.quick else 320
        if os.path.exists(cache):
            params = OrderedDict(qtz.load(cache))
            self.log("jamba: using cached weights")
        else:
            self.log(f"jamba: training hybrid {steps} steps "
                     f"({cfg.n_params()/1e6:.2f}M params)")
            params = self._train_jamba(cfg, steps)
            qtz.save(cache, params)
        self.log("jamba: calibrating")
        sites, chan = jm.calibrate(cfg, params, self.train_stream,
                                   n_samples=8 if self.quick else 24)
        T = 32 if self.quick else 64
        for combo in jm.TABLE4_COMBOS:
            t0 = time.time()
            fwd = jm.build_combo(cfg, params, sites, chan, *combo)
            tokens = jax.ShapeDtypeStruct((8, T), np.int32)
            text = to_hlo_text(lambda tok: (fwd(tok),), [tokens])
            cname = "_".join(combo)
            name = f"jamba_{cname}_prefill_b8_t{T}"
            gfile = f"graphs/{name}.hlo.txt"
            with open(self._p(gfile), "w") as f:
                f.write(text)
            self.manifest["graphs"][name] = {
                "file": gfile,
                "family": "hybrid",
                "tier": "jamba",
                "method": cname,
                "kind": "prefill",
                "batch": 8,
                "seq": T,
                "weights": "",
                "inputs": ["tokens:i32"],
                "outputs": ["logits:f32"],
                "combo": jm.combo_name(combo),
            }
            self.log(f"jamba/{cname}: lowered in {time.time()-t0:.1f}s")
        self.manifest["tiers"]["jamba"] = {
            "paper_name": "Jamba-52B (hybrid analog)",
            "d_model": cfg.d_model, "n_layer": cfg.n_layer,
            "d_state": cfg.d_state, "d_conv": cfg.d_conv,
            "d_inner": cfg.d_inner, "dt_rank": cfg.dt_rank,
            "vocab": cfg.vocab, "n_params": cfg.n_params(),
        }

    def _train_jamba(self, cfg, steps):
        from . import jamba as jm

        params = {k: jnp.asarray(v) for k, v in jm.init_params(cfg).items()}
        opt = train_mod.adamw_init(params)

        def loss_fn(p, x, y):
            logits = jm.forward_fp(cfg, p, x, use_topk=True)
            return train_mod.cross_entropy(logits, y)

        @jax.jit
        def step_fn(p, o, x, y):
            loss, grads = jax.value_and_grad(loss_fn)(p, x, y)
            p, o = train_mod.adamw_update(p, grads, o, lr=3e-3)
            return p, o, loss

        gen = data_mod.batches(self.train_stream, 8, 96, seed=17)
        for it in range(steps):
            x, y = next(gen)
            params, opt, loss = step_fn(params, opt, jnp.asarray(x), jnp.asarray(y))
            if self.verbose and (it % 50 == 0 or it == steps - 1):
                print(f"  [jamba] step {it:4d} loss {float(loss):.4f}", flush=True)
        return OrderedDict((k, np.asarray(v)) for k, v in params.items())

    def finish(self):
        with open(self._p("manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=1)
        self.log(f"manifest: {len(self.manifest['graphs'])} graphs, "
                 f"{len(self.manifest['weights'])} weight bundles")

    def _p(self, rel):
        return os.path.join(self.out, rel)


def reindex(out_dir: str):
    """Rebuild manifest.json from the artifact files on disk (recovery
    path for builds that crashed after lowering but before finish())."""
    import re

    from . import jamba as jm

    b = Builder(out_dir, quick=False)
    b.manifest["data"] = {
        "calib": "data/calib.qtz", "pile_eval": "data/pile_eval.qtz",
        "wiki_eval": "data/wiki_eval.qtz", "tasks": "data/tasks.json",
        "vocab": "data/vocab.json",
    }
    for ti, (tname, cfg) in enumerate(model_mod.TIERS.items()):
        if os.path.exists(b._p(f"weights/{tname}_fp16.qtz")):
            b.manifest["tiers"][tname] = {
                "paper_name": cfg.paper_name, "d_model": cfg.d_model,
                "n_layer": cfg.n_layer, "d_state": cfg.d_state,
                "d_conv": cfg.d_conv, "d_inner": cfg.d_inner,
                "dt_rank": cfg.dt_rank, "vocab": cfg.vocab,
                "n_params": cfg.n_params(),
                "outliers": outliers_mod.OutlierSpec.for_tier(cfg, ti).stats(),
            }
    for tname, cfg in tr_mod.T_TIERS.items():
        if os.path.exists(b._p(f"weights/{tname}_fp16.qtz")):
            b.manifest["transformer_tiers"][tname] = {
                "paper_name": cfg.paper_name, "d_model": cfg.d_model,
                "n_layer": cfg.n_layer, "n_head": cfg.n_head,
                "max_ctx": cfg.max_ctx, "vocab": cfg.vocab,
                "n_params": cfg.n_params(),
            }
    if any(f.startswith("jamba_") for f in os.listdir(b._p("graphs"))):
        cfg = jm.JAMBA_TIER
        b.manifest["tiers"]["jamba"] = {
            "paper_name": "Jamba-52B (hybrid analog)", "d_model": cfg.d_model,
            "n_layer": cfg.n_layer, "d_state": cfg.d_state, "d_conv": cfg.d_conv,
            "d_inner": cfg.d_inner, "dt_rank": cfg.dt_rank, "vocab": cfg.vocab,
            "n_params": cfg.n_params(),
        }
    # weight bundles: param order = qtz file order minus shipped gains
    for fn in sorted(os.listdir(b._p("weights"))):
        key = fn[: -len(".qtz")]
        q = qtz.load(b._p(f"weights/{fn}"))
        params = [n for n in q.keys() if not n.startswith("__")]
        b.manifest["weights"][key] = {
            "file": f"weights/{fn}", "params": params,
            "bytes": int(sum(v.nbytes for n, v in q.items() if not n.startswith("__"))),
        }
    # graphs: parse the {tier}_{method}_{kind}_b{B}[_t{T}] convention
    pat = re.compile(r"^(.*)_(prefill|decode)_b(\d+)(?:_t(\d+))?\.hlo\.txt$")
    for fn in sorted(os.listdir(b._p("graphs"))):
        m = pat.match(fn)
        if not m:
            continue
        stem, kind, batch, seq = m.group(1), m.group(2), int(m.group(3)), m.group(4)
        tier = next((t for t in list(model_mod.TIERS) + list(tr_mod.T_TIERS) + ["jamba"]
                     if stem.startswith(t + "_")), None)
        if tier is None:
            continue
        method = stem[len(tier) + 1:]
        family = ("hybrid" if tier == "jamba"
                  else "transformer" if tier in tr_mod.T_TIERS else "mamba")
        b.manifest["graphs"][fn[: -len(".hlo.txt")]] = {
            "file": f"graphs/{fn}", "family": family, "tier": tier,
            "method": method, "kind": kind, "batch": batch,
            "seq": int(seq) if seq else 1,
            "weights": "" if family == "hybrid" else f"{tier}_{method}",
            "inputs": [], "outputs": [],
        }
    b.finish()


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=None, help="artifacts directory")
    ap.add_argument("--quick", action="store_true", help="tiny build for CI/pytest")
    ap.add_argument("--tiers", default=None, help="comma list (default: all)")
    ap.add_argument("--methods", default=None, help="comma list (default: full matrix)")
    ap.add_argument("--skip-transformer", action="store_true")
    ap.add_argument("--reindex", action="store_true",
                    help="rebuild manifest.json from existing artifact files")
    args = ap.parse_args(argv)

    out_dir = args.out_dir or os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    out_dir = os.path.abspath(out_dir)
    if args.reindex:
        reindex(out_dir)
        return
    b = Builder(out_dir, quick=args.quick)
    b.build_data()

    all_methods = (qconf.CORE_METHODS + qconf.PERCENTILE_METHODS
                   + qconf.TABLE9_METHODS + qconf.IO_METHODS)
    if args.quick:
        tier_list = ["m130"]
        methods = ["fp16", "quamba", "w8a8_static"]
    else:
        tier_list = list(model_mod.TIERS.keys())
        methods = all_methods
    if args.tiers:
        tier_list = args.tiers.split(",")
    if args.methods:
        methods = args.methods.split(",")

    t0 = time.time()
    for ti, tname in enumerate(model_mod.TIERS):
        if tname not in tier_list:
            continue
        cfg = model_mod.TIERS[tname]
        m = list(methods)
        if tname == "m2p8" and not args.quick and not args.methods:
            m += qconf.LOWBIT_METHODS
        b.build_mamba_tier(cfg, ti, m)

    if not args.skip_transformer and not args.quick:
        for tname in ["p2p8"]:
            if args.tiers and tname not in (args.tiers or ""):
                continue
            b.build_transformer(tr_mod.T_TIERS[tname])

    if not args.quick and (not args.tiers or "jamba" in args.tiers):
        b.build_jamba()

    b.finish()
    b.log(f"total build time {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
