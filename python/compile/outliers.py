"""Outlier injection substrate (DESIGN.md §5).

Tiny from-scratch models do not develop the extreme activation outliers
of billion-parameter pretrained Mamba, and the paper's premise rests on
them: massive outliers (≥100) in the SSM output y, and small (<10) but
scale-skewing outliers in the SSM input x. We recreate both regimes
with *fixed per-channel gain vectors* that are part of the model
definition and present throughout training:

    x_ssm ← g_x ⊙ x_ssm      (after the conv's SiLU)
    gated ← g_y ⊙ (y · SiLU(z))   (before the output projection)

Because the gains are constant diagonal maps immediately followed by
trainable linear consumers (x_proj / the scan, and out_proj), the model
*function class* is exactly unchanged — training simply learns the
1/g-compensated weights it would have learned without gains. What does
change is the tensor that deployment quantizes at those sites: it now
carries genuine channel outliers, the same mechanism (high effective
channel gain) believed to produce outliers in large pretrained models.

Gain design, matching the paper's observations:
  * y gains: ~2% of channels, magnitude 8·2^tier (8→64 across tiers,
    paper §6.2: larger models have more/stronger outliers), growing
    toward later layers (paper Fig. 8: layers near the output have
    larger outliers).
  * x gains: a single channel per layer with modest magnitude
    (2+tier), keeping |x| ≲ 10 as in paper Fig. 12 while skewing the
    abs-max scale enough that percentile clipping matters.

A second, fully *post-hoc and exactly function-preserving* injection is
also provided for the conv-input site: scale in_proj x-columns by α and
divide the matching conv weight channels — the SiLU input is untouched
(the chain in-between is linear), so the fp32 outputs are bit-identical
while the quantized `conv_in` site sees outliers.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class OutlierSpec:
    """Per-layer fixed gain vectors; part of the model definition."""

    g_x: np.ndarray   # (L, d_inner) f32
    g_y: np.ndarray   # (L, d_inner) f32

    @staticmethod
    def identity(n_layer: int, d_inner: int) -> "OutlierSpec":
        return OutlierSpec(
            g_x=np.ones((n_layer, d_inner), np.float32),
            g_y=np.ones((n_layer, d_inner), np.float32),
        )

    @staticmethod
    def for_tier(cfg, tier_index: int, seed: int = 99, k_frac_y: float = 0.02) -> "OutlierSpec":
        rng = np.random.default_rng(seed + tier_index)
        L, di = cfg.n_layer, cfg.d_inner
        g_x = np.ones((L, di), np.float32)
        g_y = np.ones((L, di), np.float32)
        k_y = max(1, int(k_frac_y * di))
        # strong enough that even the smallest tier loses accuracy under
        # naive per-tensor W8A8 (paper Table 5: the 130M model already
        # drops 7 points), growing 2× per tier (paper §6.2)
        alpha_y_base = min(12.0 * (2.0 ** tier_index), 64.0)  # 12, 24, 48, 64
        alpha_x = 3.0 + 0.5 * tier_index
        for i in range(L):
            depth = (i + 1) / L                            # later layers: larger
            ch_y = rng.choice(di, size=k_y, replace=False)
            g_y[i, ch_y] = alpha_y_base * (0.5 + depth) * rng.uniform(0.8, 1.2, k_y)
            ch_x = rng.choice(di, size=1, replace=False)
            g_x[i, ch_x] = alpha_x * rng.uniform(0.9, 1.1)
        return OutlierSpec(g_x=g_x, g_y=g_y)

    def stats(self) -> dict:
        return {
            "gx_max": float(self.g_x.max()),
            "gy_max": float(self.g_y.max()),
            "gy_outlier_channels": int((self.g_y > 1.5).sum()),
        }


def inject_conv_in(cfg, params, alpha: float = 4.0, k: int = 2, seed: int = 7):
    """Exactly function-preserving conv-input outliers: in_proj x-half
    columns × α, conv weight channels ÷ α. Returns a mutated copy."""
    rng = np.random.default_rng(seed)
    params = {key: np.array(v, copy=True) for key, v in params.items()}
    for i in range(cfg.n_layer):
        p = f"layers.{i}."
        ch = rng.choice(cfg.d_inner, size=k, replace=False)
        params[p + "in_proj.weight"][:, ch] *= alpha
        params[p + "conv1d.weight"][:, ch] /= alpha
    return params
