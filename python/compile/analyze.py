"""Figure-data exporter (paper Figures 3, 8, 12, 13 and Section I).

Runs the fp models over calibration text and dumps per-layer activation
statistics (box-plot quantiles, per-channel maxima, rotated-space
maxima) as JSON — the numbers behind the paper's distribution plots,
consumable by any plotting frontend and by the docs.

Usage (build path, after `make artifacts`):

    cd python && python -m compile.analyze --out ../artifacts/analysis.json
"""

from __future__ import annotations

import argparse
import json
import os
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import model as model_mod
from . import qtz
from . import transformer as tr_mod
from .quant import hadamard_util as hu


def tensor_stats(a: np.ndarray) -> dict:
    """Box-plot quantiles + outlier summary over a (.., C) activation."""
    flat = np.abs(a.reshape(-1))
    chan = np.abs(a.reshape(-1, a.shape[-1])).max(axis=0)
    qs = np.percentile(flat, [50, 75, 90, 99, 99.9, 100])
    return {
        "p50": float(qs[0]),
        "p75": float(qs[1]),
        "p90": float(qs[2]),
        "p99": float(qs[3]),
        "p99_9": float(qs[4]),
        "max": float(qs[5]),
        "chan_max_median": float(np.median(chan)),
        "chan_max_max": float(chan.max()),
        "outlier_channels": int((chan > 6 * max(1e-9, np.median(chan))).sum()),
    }


def analyze_mamba(artifacts: str, tier_name: str, tokens: np.ndarray) -> dict:
    cfg = model_mod.TIERS[tier_name]
    w = qtz.load(os.path.join(artifacts, f"weights/{tier_name}_fp16.qtz"))
    gains = (jnp.asarray(w.pop("__gains.g_x")), jnp.asarray(w.pop("__gains.g_y")))
    params = {k: jnp.asarray(v) for k, v in w.items()}
    _, _, _, taps = model_mod.forward_fp(cfg, params, jnp.asarray(tokens[None]),
                                         collect=True, gains=gains)
    out: dict = {"tier": tier_name, "layers": OrderedDict()}
    for i in range(cfg.n_layer):
        x = np.asarray(taps[f"l{i}.x_ssm"])
        gated = np.asarray(taps[f"l{i}.gated"])
        gated_h = np.asarray(taps[f"l{i}.gated_h"])
        out["layers"][str(i)] = {
            "x_ssm": tensor_stats(x),          # paper Fig 8 left / Fig 12 x
            "y_gated": tensor_stats(gated),    # paper Fig 8 right / Fig 12 y
            "y_rotated": tensor_stats(gated_h),
            "hadamard_suppression": float(
                np.abs(gated).max() * np.sqrt(gated.shape[-1]) / max(1e-9, np.abs(gated_h).max())
            ),
        }
    return out


def analyze_transformer(artifacts: str, tier_name: str, tokens: np.ndarray) -> dict:
    cfg = tr_mod.T_TIERS[tier_name]
    w = qtz.load(os.path.join(artifacts, f"weights/{tier_name}_fp16.qtz"))
    params = {k: jnp.asarray(v) for k, v in w.items()}
    # bound the cache to the sample length for speed
    small = tr_mod.TransformerTier(
        name=cfg.name, paper_name=cfg.paper_name, d_model=cfg.d_model,
        n_layer=cfg.n_layer, n_head=cfg.n_head, max_ctx=len(tokens), vocab=cfg.vocab)
    _, _, _, taps = tr_mod.forward_fp(small, params, jnp.asarray(tokens[None].astype(np.int32)),
                                      collect=True)
    out: dict = {"tier": tier_name, "layers": OrderedDict()}
    for i in range(cfg.n_layer):
        out["layers"][str(i)] = {
            "attn_out_y": tensor_stats(np.asarray(taps[f"l{i}.attn_out"])),  # Fig 13: smooth
            "mlp_hidden_h_d": tensor_stats(np.asarray(taps[f"l{i}.h_d"])),   # Fig 13: outliers
        }
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--artifacts", default="../artifacts")
    ap.add_argument("--out", default="../artifacts/analysis.json")
    ap.add_argument("--tokens", type=int, default=256)
    args = ap.parse_args(argv)

    stream = qtz.load(os.path.join(args.artifacts, "data/pile_eval.qtz"))["tokens"]
    toks = stream[: args.tokens].astype(np.int32)
    with open(os.path.join(args.artifacts, "manifest.json")) as f:
        mani = json.load(f)
    report: dict = {"mamba": {}, "transformer": {}}
    for tier in mani["tiers"]:
        if tier in model_mod.TIERS:
            print(f"[analyze] mamba {tier}")
            report["mamba"][tier] = analyze_mamba(args.artifacts, tier, toks)
    for tier in mani.get("transformer_tiers", {}):
        if tier in tr_mod.T_TIERS:
            print(f"[analyze] transformer {tier}")
            report["transformer"][tier] = analyze_transformer(args.artifacts, tier, toks)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"[analyze] wrote {args.out}")
    # quick textual digest (the paper's qualitative claims)
    for tier, rep in report["mamba"].items():
        last = rep["layers"][str(len(rep["layers"]) - 1)]
        print(
            f"  {tier}: x p99={last['x_ssm']['p99']:.2f} max={last['x_ssm']['max']:.2f} | "
            f"y max={last['y_gated']['max']:.1f} outlier_ch={last['y_gated']['outlier_channels']} | "
            f"H-suppression {last['hadamard_suppression']:.1f}x"
        )


if __name__ == "__main__":
    main()
