"""Pythia-like Transformer baseline (paper Figures 1b/1c/10, Table 2/3
`Pythia` rows): a pre-norm GPT-NeoX-style decoder with rotary-free
learned positions kept out (we use RoPE-free causal attention with a
learned absolute embedding folded away — positions are encoded with a
simple ALiBi-style linear bias, which keeps the decode-step graph free
of a position input), KV-cache decode step, and the same vocabulary /
tier scheme as the Mamba models so iso-size comparisons are direct.

The serving-relevant property this baseline exists to demonstrate is
the paper's Figure 1(c): the KV cache grows linearly with context
while the SSM state is constant — the rust state manager implements
both pools and regenerates that figure.

Quantization: the `w8a8_static` and `smoothquant` recipes apply to the
linear layers (q/k/v/o and the MLP), with attention probabilities and
softmax in fp — mirroring how SmoothQuant treats Transformers and
enabling the Figure 10 sensitivity comparison.
"""

from __future__ import annotations

import math
from collections import OrderedDict
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from .kernels import ref
from .quant import core as qc


@dataclass(frozen=True)
class TransformerTier:
    name: str
    paper_name: str
    d_model: int
    n_layer: int
    n_head: int
    max_ctx: int = 2048
    vocab: int = data_mod.VOCAB_SIZE
    eps: float = 1e-5

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_head

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model

    def n_params(self) -> int:
        d = self.d_model
        per_layer = 2 * d + 4 * d * d + 2 * d * self.d_ff + self.d_ff + d
        return self.vocab * d + d + self.n_layer * per_layer


T_TIERS = OrderedDict(
    (t.name, t)
    for t in [
        TransformerTier("p1p4", "Pythia-1.4B", d_model=128, n_layer=4, n_head=4),
        TransformerTier("p2p8", "Pythia-2.8B", d_model=160, n_layer=5, n_head=5),
    ]
)


def param_names(cfg: TransformerTier) -> list:
    names = ["embedding.weight"]
    for i in range(cfg.n_layer):
        p = f"layers.{i}."
        names += [
            p + "norm1.weight", p + "wqkv", p + "wo",
            p + "norm2.weight", p + "w1", p + "b1", p + "w2",
        ]
    names += ["norm_f.weight"]
    return names


def init_params(cfg: TransformerTier, seed: int = 1) -> "OrderedDict[str, np.ndarray]":
    rng = np.random.default_rng(seed)
    d, ff = cfg.d_model, cfg.d_ff
    P: "OrderedDict[str, np.ndarray]" = OrderedDict()

    def dense(shape):
        return rng.uniform(-1, 1, size=shape).astype(np.float32) / math.sqrt(shape[0])

    P["embedding.weight"] = rng.normal(0, 0.02, size=(cfg.vocab, d)).astype(np.float32)
    for i in range(cfg.n_layer):
        p = f"layers.{i}."
        P[p + "norm1.weight"] = np.ones(d, np.float32)
        P[p + "wqkv"] = dense((d, 3 * d))
        P[p + "wo"] = dense((d, d))
        P[p + "norm2.weight"] = np.ones(d, np.float32)
        P[p + "w1"] = dense((d, ff))
        P[p + "b1"] = np.zeros(ff, np.float32)
        P[p + "w2"] = dense((ff, d))
    P["norm_f.weight"] = np.ones(d, np.float32)
    return P


def _alibi_slopes(n_head: int) -> np.ndarray:
    return np.array([2.0 ** (-(i + 1) * 8.0 / n_head) for i in range(n_head)], np.float32)


def _attn(cfg, q, k, v, pos_q, pos_k):
    """Causal attention with ALiBi bias. q: (B,Tq,H,Dh), k/v: (B,Tk,H,Dh);
    pos_q/pos_k are absolute position vectors (Tq,), (Tk,)."""
    scale = 1.0 / math.sqrt(cfg.d_head)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    slopes = jnp.asarray(_alibi_slopes(cfg.n_head))
    dist = pos_q[:, None] - pos_k[None, :]
    bias = -slopes[:, None, None] * jnp.maximum(dist, 0).astype(jnp.float32)
    mask = dist >= 0
    logits = logits + bias[None]
    logits = jnp.where(mask[None, None], logits, -1e9)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def forward_fp(cfg: TransformerTier, params, tokens, k_cache=None, v_cache=None, cache_len=0,
               collect=False):
    """fp32 forward with optional KV cache.

    Prefill: tokens (B, T), caches None → returns logits (B,T,V) and the
    (L, B, max_ctx, H, Dh) caches filled at [0, T).
    Decode: tokens (B, 1), caches present, `cache_len` scalar position.
    """
    B, T = tokens.shape
    H, Dh, L, M = cfg.n_head, cfg.d_head, cfg.n_layer, cfg.max_ctx
    taps = OrderedDict() if collect else None
    if k_cache is None:
        k_cache = jnp.zeros((L, B, M, H, Dh), jnp.float32)
        v_cache = jnp.zeros((L, B, M, H, Dh), jnp.float32)
    resid = params["embedding.weight"][tokens]
    pos_q = cache_len + jnp.arange(T)
    new_k, new_v = [], []
    for i in range(L):
        p = f"layers.{i}."
        h = ref.rmsnorm(resid, params[p + "norm1.weight"], cfg.eps)
        if taps is not None:
            taps[f"l{i}.attn_in"] = h
        qkv = h @ params[p + "wqkv"]
        if taps is not None:
            taps[f"l{i}.qkv"] = qkv
        q, k, v = jnp.split(qkv.reshape(B, T, 3, H, Dh), 3, axis=2)
        q, k, v = q[:, :, 0], k[:, :, 0], v[:, :, 0]
        kc = jax.lax.dynamic_update_slice(k_cache[i], k, (0, cache_len, 0, 0))
        vc = jax.lax.dynamic_update_slice(v_cache[i], v, (0, cache_len, 0, 0))
        new_k.append(kc)
        new_v.append(vc)
        pos_k = jnp.arange(M)
        attn = _attn(cfg, q, kc, vc, pos_q, pos_k)
        # mask out cache slots beyond the live length
        attn_out = attn.reshape(B, T, H * Dh)
        if taps is not None:
            taps[f"l{i}.attn_out"] = attn_out
        resid = resid + attn_out @ params[p + "wo"]
        h2 = ref.rmsnorm(resid, params[p + "norm2.weight"], cfg.eps)
        if taps is not None:
            taps[f"l{i}.mlp_in"] = h2
        hd = jax.nn.gelu(h2 @ params[p + "w1"] + params[p + "b1"])
        if taps is not None:
            taps[f"l{i}.h_d"] = hd
        resid = resid + hd @ params[p + "w2"]
    final = ref.rmsnorm(resid, params["norm_f.weight"], cfg.eps)
    if taps is not None:
        taps["head_in"] = final
    logits = final @ params["embedding.weight"].T
    out = (logits, jnp.stack(new_k), jnp.stack(new_v))
    return out + (taps,) if collect else out


def forward_q(cfg: TransformerTier, method, params, wq, wscales, ascales, tokens,
              k_cache=None, v_cache=None, cache_len=0):
    """W8A8 transformer: int8 GEMMs on the projections, attention math
    in fp (standard SmoothQuant precision mapping)."""
    B, T = tokens.shape
    H, Dh, L, M = cfg.n_head, cfg.d_head, cfg.n_layer, cfg.max_ctx
    if k_cache is None:
        k_cache = jnp.zeros((L, B, M, H, Dh), jnp.float32)
        v_cache = jnp.zeros((L, B, M, H, Dh), jnp.float32)
    resid = wq["embedding.weight"][tokens]
    pos_q = cache_len + jnp.arange(T)
    new_k, new_v = [], []
    for i in range(L):
        p = f"layers.{i}."
        h = ref.rmsnorm(resid, wq[p + "norm1.weight"], cfg.eps)
        h8 = qc.quantize_sym(h, ascales[p + "wqkv.in_s"], 8)
        qkv = ref.matmul_i8(h8, wq[p + "wqkv"], ascales[p + "wqkv.in_s"], wscales[p + "wqkv.s"])
        q, k, v = jnp.split(qkv.reshape(B, T, 3, H, Dh), 3, axis=2)
        q, k, v = q[:, :, 0], k[:, :, 0], v[:, :, 0]
        kc = jax.lax.dynamic_update_slice(k_cache[i], k, (0, cache_len, 0, 0))
        vc = jax.lax.dynamic_update_slice(v_cache[i], v, (0, cache_len, 0, 0))
        new_k.append(kc)
        new_v.append(vc)
        attn = _attn(cfg, q, kc, vc, pos_q, jnp.arange(M)).reshape(B, T, H * Dh)
        a8 = qc.quantize_sym(attn, ascales[p + "wo.in_s"], 8)
        resid = resid + ref.matmul_i8(a8, wq[p + "wo"], ascales[p + "wo.in_s"], wscales[p + "wo.s"])
        h2 = ref.rmsnorm(resid, wq[p + "norm2.weight"], cfg.eps)
        h28 = qc.quantize_sym(h2, ascales[p + "w1.in_s"], 8)
        hd = jax.nn.gelu(ref.matmul_i8(h28, wq[p + "w1"], ascales[p + "w1.in_s"],
                                       wscales[p + "w1.s"], bias=wq[p + "b1"]))
        hd8 = qc.quantize_sym(hd, ascales[p + "w2.in_s"], 8)
        resid = resid + ref.matmul_i8(hd8, wq[p + "w2"], ascales[p + "w2.in_s"], wscales[p + "w2.s"])
    final = ref.rmsnorm(resid, wq["norm_f.weight"], cfg.eps)
    h8 = qc.quantize_sym(final, ascales["head.in_s"], 8)
    logits = ref.matmul_i8(h8, wq["lm_head.weight"], ascales["head.in_s"], wscales["lm_head.weight.s"])
    return logits, jnp.stack(new_k), jnp.stack(new_v)


def calibrate_and_quantize(cfg, params, stream, method, n_samples=32, seqlen=128, batch=8,
                           smooth_alpha=None):
    """Collect per-site amax for the transformer, fold SmoothQuant if
    requested, and return (wq, wscales, ascales)."""
    params_j = {k: jnp.asarray(v) for k, v in params.items()}

    @jax.jit
    def fwd(tokens):
        _, _, _, taps = forward_fp(cfg, params_j, tokens, collect=True)
        return taps

    gen = data_mod.batches(stream, batch, seqlen, seed=321)
    amax: dict = {}
    chan: dict = {}
    for _ in range(max(1, n_samples // batch)):
        x, _ = next(gen)
        taps = jax.device_get(fwd(jnp.asarray(x)))
        for site, v in taps.items():
            a = np.abs(np.asarray(v, np.float32))
            amax[site] = max(amax.get(site, 0.0), float(a.max()))
            cam = a.reshape(-1, a.shape[-1]).max(axis=0)
            chan[site] = np.maximum(chan.get(site, 0.0), cam)

    from .quant.smoothquant import fold_linear

    wq: "OrderedDict[str, np.ndarray]" = OrderedDict()
    wscales: dict = {}
    ascales: dict = {}
    wq["embedding.weight"] = params["embedding.weight"].astype(np.float32)
    site_of = {"wqkv": "attn_in", "wo": "attn_out", "w1": "mlp_in", "w2": "h_d"}
    for i in range(cfg.n_layer):
        p = f"layers.{i}."
        wq[p + "norm1.weight"] = params[p + "norm1.weight"].astype(np.float32)
        wq[p + "norm2.weight"] = params[p + "norm2.weight"].astype(np.float32)
        wq[p + "b1"] = params[p + "b1"].astype(np.float32)
        for leaf in ("wqkv", "wo", "w1", "w2"):
            w = params[p + leaf].astype(np.float32)
            site = f"l{i}.{site_of[leaf]}"
            a = amax[site]
            if smooth_alpha is not None and leaf in ("wqkv", "w1"):
                s, w = fold_linear(chan[site], w, smooth_alpha)
                if leaf == "wqkv":
                    wq[p + "norm1.weight"] = wq[p + "norm1.weight"] / s
                else:
                    wq[p + "norm2.weight"] = wq[p + "norm2.weight"] / s
                a = float((chan[site] / s).max())
            q, sw = qc.quantize_weight_np(w, 8)
            wq[p + leaf] = q
            wscales[p + leaf + ".s"] = float(sw)
            ascales[p + leaf + ".in_s"] = float(qc.scale_sym(a, 8))
    wq["norm_f.weight"] = params["norm_f.weight"].astype(np.float32)
    q, sw = qc.quantize_weight_np(params["embedding.weight"].T.copy(), 8)
    wq["lm_head.weight"] = q
    wscales["lm_head.weight.s"] = float(sw)
    # final-norm output amax ≈ head input; reuse the last mlp_in bound
    ascales["head.in_s"] = float(qc.scale_sym(amax.get("head_in", max(amax.values())), 8))
    return wq, wscales, ascales
