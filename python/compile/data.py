"""Deterministic synthetic language substrate.

The paper trains/evaluates on Pile / WikiText2 / LAMBADA / HellaSwag /
PIQA / ARC / WinoGrande — none of which we can ship. This module builds
the closest synthetic equivalents that exercise the same code paths:

* a 256-word procedural vocabulary (syllable combinator, seeded),
* a second-order Markov "English" generator with Zipfian unigram
  marginals and per-style topic mixtures — two styles give us distinct
  "pile-synth" (training + calibration + eval) and "wiki-synth"
  (eval-only, mildly out-of-distribution) corpora,
* six procedural zero-shot tasks mirroring the paper's suite:
  - lambada_synth    : predict the last word of a long passage (the
                       passage deterministically re-mentions the target)
  - hellaswag_synth  : choose the most likely 8-token continuation (4-way)
  - piqa_synth       : 2-way continuation choice
  - arc_easy_synth   : 4-way, distractors drawn from frequent words
  - arc_chal_synth   : 4-way, distractors drawn from plausible bigrams
  - winogrande_synth : 2-way fill-in with a re-mention cue

Everything is a pure function of the seed, so python (training) and the
rust eval harness (which reads the emitted token bins / task JSON) see
identical data across rebuilds.

Token space: 0 = PAD, 1 = BOS, 2 = EOS, 3 = SEP, 4.. = words.
"""

from __future__ import annotations

import json
from collections import OrderedDict

import numpy as np

PAD, BOS, EOS, SEP = 0, 1, 2, 3
N_SPECIAL = 4
VOCAB_SIZE = 256
N_WORDS = VOCAB_SIZE - N_SPECIAL

_ONSETS = ["b", "d", "f", "g", "k", "l", "m", "n", "p", "r", "s", "t", "v", "z", "ch", "st"]
_NUCLEI = ["a", "e", "i", "o", "u", "ai", "ou"]
_CODAS = ["", "n", "r", "s", "t", "l", "m"]


def build_words(seed: int = 7) -> list:
    """Procedurally generate ``N_WORDS`` distinct pronounceable words."""
    rng = np.random.default_rng(seed)
    words, seen = [], set()
    while len(words) < N_WORDS:
        n_syll = 1 + int(rng.integers(0, 3))
        w = "".join(
            _ONSETS[int(rng.integers(len(_ONSETS)))]
            + _NUCLEI[int(rng.integers(len(_NUCLEI)))]
            + _CODAS[int(rng.integers(len(_CODAS)))]
            for _ in range(n_syll)
        )
        if w not in seen and 2 <= len(w) <= 12:
            seen.add(w)
            words.append(w)
    return words


class Vocab:
    def __init__(self, seed: int = 7):
        self.words = build_words(seed)
        self.id_of = {w: i + N_SPECIAL for i, w in enumerate(self.words)}

    def decode(self, ids) -> str:
        toks = []
        for t in ids:
            t = int(t)
            if t == BOS:
                continue
            if t == EOS:
                break
            toks.append("<sep>" if t == SEP else (self.words[t - N_SPECIAL] if t >= N_SPECIAL else "<pad>"))
        return " ".join(toks)

    def to_json(self) -> str:
        return json.dumps({"special": ["<pad>", "<bos>", "<eos>", "<sep>"], "words": self.words})


class MarkovLM:
    """Second-order Markov chain over word ids with Zipfian marginals.

    The transition structure is low-rank-ish: each word belongs to one of
    ``n_topics`` topics; next-word logits = zipf prior + topic affinity +
    a seeded bigram bonus table. ``style`` shifts the topic mixture so
    two styles produce measurably different distributions (distinct
    eval perplexities, like Wiki2 vs Pile).
    """

    def __init__(self, seed: int = 11, n_topics: int = 8, style: int = 0):
        rng = np.random.default_rng(seed + 1000 * style)
        self.rng = rng
        ranks = np.arange(1, N_WORDS + 1, dtype=np.float64)
        zipf = 1.0 / ranks**1.05
        self.log_prior = np.log(zipf / zipf.sum())
        self.topic_of = rng.integers(0, n_topics, size=N_WORDS)
        self.affinity = rng.normal(0.0, 1.0, size=(n_topics, n_topics))
        # style skews which topics talk to which
        self.affinity += 0.8 * rng.normal(0.0, 1.0, size=(n_topics, n_topics)) * style
        # sparse bigram bonuses make some continuations strongly preferred
        self.bigram_bonus = np.zeros((N_WORDS, N_WORDS))
        n_bonus = 6 * N_WORDS
        ii = rng.integers(0, N_WORDS, n_bonus)
        jj = rng.integers(0, N_WORDS, n_bonus)
        self.bigram_bonus[ii, jj] = rng.uniform(2.0, 4.0, n_bonus)
        self._row_cache = {}

    def next_dist(self, w1: int, w2: int) -> np.ndarray:
        """P(next | prev2=w1, prev=w2) over word indices [0, N_WORDS)."""
        key = (w1, w2)
        p = self._row_cache.get(key)
        if p is None:
            logits = (
                self.log_prior
                + 1.2 * self.affinity[self.topic_of[w2]][self.topic_of]
                + 0.4 * self.affinity[self.topic_of[w1]][self.topic_of]
                + self.bigram_bonus[w2]
            )
            logits -= logits.max()
            p = np.exp(logits)
            p /= p.sum()
            if len(self._row_cache) < 60000:
                self._row_cache[key] = p
        return p

    def sample_tokens(self, n: int, rng: np.random.Generator) -> np.ndarray:
        """Sample a token stream of length ``n`` (word ids + sentence SEPs)."""
        out = np.empty(n, dtype=np.uint16)
        w1 = int(rng.integers(0, N_WORDS))
        w2 = int(rng.integers(0, N_WORDS))
        sent_len = 0
        for i in range(n):
            if sent_len > 6 and rng.random() < 0.12:
                out[i] = SEP
                sent_len = 0
                continue
            p = self.next_dist(w1, w2)
            w = int(rng.choice(N_WORDS, p=p))
            out[i] = w + N_SPECIAL
            w1, w2 = w2, w
            sent_len += 1
        return out

    def greedy_next(self, w1: int, w2: int) -> int:
        return int(np.argmax(self.next_dist(w1, w2)))


def make_corpora(seed: int = 11):
    """Return (pile_lm, wiki_lm) — two styles of the generator."""
    return MarkovLM(seed=seed, style=0), MarkovLM(seed=seed, style=1)


def token_stream(lm: MarkovLM, n_tokens: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return lm.sample_tokens(n_tokens, rng)


# ---------------------------------------------------------------------------
# Zero-shot task suite
# ---------------------------------------------------------------------------

def _passage(lm: MarkovLM, rng, n: int) -> list:
    return list(lm.sample_tokens(n, rng))


def make_lambada(lm: MarkovLM, rng, n_ex: int) -> list:
    """Last-word prediction (LAMBADA-style exact match). The target is
    the generator's modal continuation of the passage — recoverable by
    any model that learned the corpus distribution (exactly the
    training objective), and the first casualty when quantization noise
    pushes the argmax off the mode. Only confidently-peaked contexts
    are kept (mode probability ≥ 0.25) so the FP ceiling is high and
    the measured drop is quantization, not task noise."""
    exs = []
    while len(exs) < n_ex:
        ctx = list(_passage(lm, rng, 48))
        words = [t - N_SPECIAL for t in ctx if t >= N_SPECIAL]
        if len(words) < 2:
            continue
        w1, w2 = words[-2], words[-1]
        # ensure the passage *ends* with the two cue words
        if ctx[-1] != w2 + N_SPECIAL or ctx[-2] != w1 + N_SPECIAL:
            ctx = ctx[: len(ctx) - 1]
            ctx += [w1 + N_SPECIAL, w2 + N_SPECIAL]
        p = lm.next_dist(w1, w2)
        if p.max() < 0.25:
            continue
        target = int(np.argmax(p)) + N_SPECIAL
        exs.append({"prompt": ctx, "target": [target]})
    return exs


def _choice_task(lm: MarkovLM, rng, n_ex: int, n_choices: int, cont_len: int, distractor: str) -> list:
    exs = []
    for _ in range(n_ex):
        ctx = _passage(lm, rng, 24)
        w1 = next((t - N_SPECIAL for t in reversed(ctx[:-1]) if t >= N_SPECIAL), 0)
        w2 = ctx[-1] - N_SPECIAL if ctx[-1] >= N_SPECIAL else 0
        # gold continuation = greedy rollout of the generator
        gold, a, b = [], w1, w2
        for _ in range(cont_len):
            w = lm.greedy_next(a, b)
            gold.append(w + N_SPECIAL)
            a, b = b, w
        choices = [gold]
        while len(choices) < n_choices:
            if distractor == "frequent":
                c = [int(rng.integers(0, 24)) + N_SPECIAL for _ in range(cont_len)]
            elif distractor == "bigram":
                # plausible-but-wrong: greedy rollout from a random state
                c, a2, b2 = [], int(rng.integers(0, N_WORDS)), int(rng.integers(0, N_WORDS))
                for _ in range(cont_len):
                    w = lm.greedy_next(a2, b2)
                    c.append(w + N_SPECIAL)
                    a2, b2 = b2, w
            else:  # uniform
                c = [int(rng.integers(0, N_WORDS)) + N_SPECIAL for _ in range(cont_len)]
            if c != gold:
                choices.append(c)
        order = rng.permutation(n_choices)
        exs.append(
            {
                "prompt": ctx,
                "choices": [choices[i] for i in order],
                "gold": int(np.argwhere(order == 0)[0][0]),
            }
        )
    return exs


def make_winogrande(lm: MarkovLM, rng, n_ex: int) -> list:
    """2-way fill-in: context mentions entity A repeatedly; the question
    asks which of {A, B} follows a cue."""
    exs = []
    for _ in range(n_ex):
        a_tok = int(rng.integers(0, N_WORDS)) + N_SPECIAL
        b_tok = int(rng.integers(0, N_WORDS)) + N_SPECIAL
        if a_tok == b_tok:
            continue
        ctx = _passage(lm, rng, 32)
        for pos in sorted(rng.choice(np.arange(4, 28), 4, replace=False)):
            ctx[int(pos)] = a_tok
        prompt = ctx + [SEP]
        choices = [[a_tok], [b_tok]]
        order = rng.permutation(2)
        exs.append({"prompt": prompt, "choices": [choices[i] for i in order],
                    "gold": int(np.argwhere(order == 0)[0][0])})
    return exs


def build_task_suite(lm: MarkovLM, seed: int = 23, n_ex: int = 120) -> "OrderedDict[str, dict]":
    rng = np.random.default_rng(seed)
    suite = OrderedDict()
    suite["lambada_synth"] = {"kind": "exact_last", "examples": make_lambada(lm, rng, n_ex)}
    suite["hellaswag_synth"] = {
        "kind": "choice_norm",  # accuracy normalized by length, like the paper
        "examples": _choice_task(lm, rng, n_ex, 4, 8, "bigram"),
    }
    suite["piqa_synth"] = {"kind": "choice", "examples": _choice_task(lm, rng, n_ex, 2, 6, "uniform")}
    suite["arc_easy_synth"] = {"kind": "choice", "examples": _choice_task(lm, rng, n_ex, 4, 4, "frequent")}
    suite["arc_chal_synth"] = {"kind": "choice_norm", "examples": _choice_task(lm, rng, n_ex, 4, 4, "bigram")}
    suite["winogrande_synth"] = {"kind": "choice", "examples": make_winogrande(lm, rng, n_ex)}
    return suite


def batches(stream: np.ndarray, batch: int, seqlen: int, seed: int):
    """Yield (inputs, targets) next-token batches forever from a stream."""
    rng = np.random.default_rng(seed)
    n = len(stream) - seqlen - 1
    while True:
        idx = rng.integers(0, n, size=batch)
        x = np.stack([stream[i : i + seqlen] for i in idx]).astype(np.int32)
        y = np.stack([stream[i + 1 : i + seqlen + 1] for i in idx]).astype(np.int32)
        yield x, y
