"""QTZ: a tiny self-describing binary tensor container.

The build path (python) writes model weights, quantized weights, scales
and token streams into ``.qtz`` files; the rust runtime reads them with
``rust/src/tensor/qtz.rs``. The format is deliberately trivial so both
sides can implement it in ~100 lines with zero dependencies:

    magic   : 4 bytes  b"QTZ1"
    count   : u32 LE   number of tensors
    then per tensor:
      name_len : u16 LE
      name     : utf-8 bytes
      dtype    : u8     (0=f32, 1=i8, 2=i32, 3=u16, 4=i64, 5=u8)
      ndim     : u8
      dims     : ndim * u32 LE
      data     : product(dims) * itemsize bytes, little endian, C order

All multi-byte values are little-endian. Tensors are stored in
insertion order; readers must preserve it (the artifact manifest refers
to parameter positions by name, but order makes files diffable).
"""

from __future__ import annotations

import struct
from collections import OrderedDict

import numpy as np

MAGIC = b"QTZ1"

# dtype code <-> numpy dtype
_DTYPES = {
    0: np.dtype("<f4"),
    1: np.dtype("i1"),
    2: np.dtype("<i4"),
    3: np.dtype("<u2"),
    4: np.dtype("<i8"),
    5: np.dtype("u1"),
}
_CODES = {v: k for k, v in _DTYPES.items()}


def dtype_code(dt: np.dtype) -> int:
    dt = np.dtype(dt).newbyteorder("<") if np.dtype(dt).itemsize > 1 else np.dtype(dt)
    if dt not in _CODES:
        raise ValueError(f"unsupported dtype for qtz: {dt}")
    return _CODES[dt]


def save(path: str, tensors: "OrderedDict[str, np.ndarray] | dict") -> None:
    """Write a dict of name -> ndarray to ``path``."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.asarray(arr)
            if arr.ndim > 0:
                arr = np.ascontiguousarray(arr)
            code = dtype_code(arr.dtype)
            nb = name.encode("utf-8")
            if len(nb) > 0xFFFF:
                raise ValueError("tensor name too long")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", code, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.astype(_DTYPES[code], copy=False).tobytes(order="C"))


def load(path: str) -> "OrderedDict[str, np.ndarray]":
    """Read a ``.qtz`` file back into an ordered dict of ndarrays."""
    out: "OrderedDict[str, np.ndarray]" = OrderedDict()
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError(f"{path}: bad magic (not a QTZ1 file)")
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode("utf-8")
            code, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            dt = _DTYPES[code]
            n = int(np.prod(dims)) if ndim else 1
            buf = f.read(n * dt.itemsize)
            out[name] = np.frombuffer(buf, dtype=dt).reshape(dims).copy()
    return out
