"""Quantization library for the Quamba reproduction.

Submodules:
  core          symmetric / asymmetric / percentile / log2 quantizers
  hadamard_util Walsh-Hadamard + Paley constructions (H12, H20), FWHT
  config        method descriptors (which recipe each paper baseline uses)
  calibrate     activation observers -> static scale sets
  smoothquant   SmoothQuant-SSM (alpha-folding for Mamba linears)
  quarot        QuaRot-SSM rotations (W8A8 and W4A4)
  lowbit        Quip#-like W2A16 weight-only quantization
  mixed         LLM.int8-style mixed-precision decomposition
"""

from . import core, hadamard_util, config  # noqa: F401

# calibrate/smoothquant/quarot/lowbit/mixed are imported lazily by their
# users (they depend on the kernels package, which imports back into
# quant.core — eager importing here would be circular).
