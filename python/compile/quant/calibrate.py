"""Calibration: activation observers → static per-tensor scale sets.

Mirrors the paper §5.1: run the fp model over a calibration set sampled
from the training corpus, record the absolute maximum (and percentile
maxima, per-channel maxima, min/max for the asymmetric ablation, and
rotated-space maxima) per activation site, then derive every method's
`QuantArtifacts` (quantized weights + baked scales) without touching
the data again. The same scale set is reused by every experiment.
"""

from __future__ import annotations

from collections import OrderedDict, defaultdict

import jax
import jax.numpy as jnp
import numpy as np

from .. import model as model_mod
from . import core as qc
from . import hadamard_util as hu
from .config import Method

PERCENTILES = (99.0, 99.9, 99.99, 99.999)


class CalibStats:
    """Running activation statistics keyed by tap-site name."""

    def __init__(self):
        self.amax = defaultdict(float)                 # site -> max |x|
        self.pctl = defaultdict(lambda: defaultdict(list))  # site -> p -> [per-batch pctl]
        self.vmin = defaultdict(lambda: float("inf"))
        self.vmax = defaultdict(lambda: float("-inf"))
        self.chan_amax = {}                            # site -> per-channel max |x|
        self.rot_amax = defaultdict(float)             # site -> max |H x|
        self.n_batches = 0

    def update(self, taps):
        for site, v in taps.items():
            a = np.asarray(v, dtype=np.float32)
            ax = np.abs(a)
            self.amax[site] = max(self.amax[site], float(ax.max()))
            self.vmin[site] = min(self.vmin[site], float(a.min()))
            self.vmax[site] = max(self.vmax[site], float(a.max()))
            for p in PERCENTILES:
                self.pctl[site][p].append(float(np.percentile(ax.reshape(-1), p)))
            cam = ax.reshape(-1, a.shape[-1]).max(axis=0)
            if site in self.chan_amax:
                self.chan_amax[site] = np.maximum(self.chan_amax[site], cam)
            else:
                self.chan_amax[site] = cam
            # rotated-space amax (only meaningful for power-friendly dims)
            try:
                r = np.asarray(hu.fwht(a.reshape(-1, a.shape[-1])))
                self.rot_amax[site] = max(self.rot_amax[site], float(np.abs(r).max()))
            except ValueError:
                pass
        self.n_batches += 1

    def percentile_amax(self, site: str, p: float) -> float:
        """Across-batch aggregate of the per-batch percentile maxima."""
        if p >= 100.0:
            return self.amax[site]
        return float(np.mean(self.pctl[site][p]))


def calibrate(cfg, params, stream: np.ndarray, n_samples: int = 64, seqlen: int = 256,
              batch: int = 8, seed: int = 123, gains=None) -> CalibStats:
    """Run the fp model over `n_samples` calibration sequences."""
    params_j = {k: jnp.asarray(v) for k, v in params.items()}
    gains_j = None if gains is None else (jnp.asarray(gains.g_x), jnp.asarray(gains.g_y))

    @jax.jit
    def fwd(tokens):
        logits, c, s, taps = model_mod.forward_fp(cfg, params_j, tokens, collect=True,
                                                  gains=gains_j)
        return taps

    stats = CalibStats()
    gen = model_mod.data_mod.batches(stream, batch, seqlen, seed)
    for _ in range(max(1, n_samples // batch)):
        x, _ = next(gen)
        stats.update(jax.device_get(fwd(jnp.asarray(x))))
    return stats


# ---------------------------------------------------------------------------
# Per-method artifact construction
# ---------------------------------------------------------------------------

def _smooth_vec(act_chan_amax: np.ndarray, w_chan_amax: np.ndarray, alpha: float) -> np.ndarray:
    s = np.power(np.maximum(act_chan_amax, 1e-5), alpha) / np.power(
        np.maximum(w_chan_amax, 1e-5), 1.0 - alpha
    )
    return np.clip(s, 1e-2, 1e2).astype(np.float32)


def build_artifacts(cfg, params, method: Method, stats: CalibStats):
    """Produce the runtime weights + baked scales for one method.

    Weight folds applied here, offline (zero runtime cost — the paper's
    compute-invariance argument, §4.2):
      * Hadamard:   W_out ← H_di · W_out   (wscale absorbs 1/d_inner)
      * QuaRot:     W_in  ← H_d  · W_in    (wscale absorbs 1/d_model)
      * SmoothQuant: norm.weight ← norm.weight / s_ch,
                     W_in ← diag(s_ch) · W_in  (exact, α = 0.5)
    """
    from . import lowbit  # local import (circular-free)

    if method.weight_only:
        return lowbit.build_weight_only(cfg, params, method)

    nb = method.w_bits
    weights: "OrderedDict[str, np.ndarray]" = OrderedDict()
    wscales: dict = {}
    ascales: dict = {}

    weights["embedding.weight"] = params["embedding.weight"].astype(np.float32)

    for i in range(cfg.n_layer):
        p = f"layers.{i}."
        norm_w = params[p + "norm.weight"].astype(np.float32).copy()
        w_in = params[p + "in_proj.weight"].astype(np.float32).copy()
        w_out = params[p + "out_proj.weight"].astype(np.float32).copy()

        if method.smooth_alpha is not None:
            # fold smoothing into (norm, in_proj): exact
            s_ch = _smooth_vec(stats.chan_amax[f"l{i}.resid_in"],
                               np.abs(w_in).max(axis=1), method.smooth_alpha)
            norm_w /= s_ch
            w_in *= s_ch[:, None]
            # post-smooth activation amax: per-channel amax / s_ch
            sm_in = stats.chan_amax[f"l{i}.resid_in"] / s_ch
            ascales[p + "in_proj.weight.in_s"] = float(qc.scale_sym(float(sm_in.max()), method.a_bits))
            # out_proj smoothing: explicit divide in-graph
            s_chy = _smooth_vec(stats.chan_amax[f"l{i}.gated"],
                                np.abs(w_out).max(axis=1), method.smooth_alpha)
            ascales[f"l{i}.smooth_y_inv"] = (1.0 / s_chy).astype(np.float32)
            w_out = w_out * s_chy[:, None]
            sm_y = stats.chan_amax[f"l{i}.gated"] / s_chy
            ascales[f"l{i}.gated.s"] = float(qc.scale_sym(float(sm_y.max()), method.a_bits))
        else:
            ascales[p + "in_proj.weight.in_s"] = float(
                qc.scale_sym(stats.amax[f"l{i}.resid_in"], method.a_bits))
            ascales[f"l{i}.gated.s"] = float(qc.scale_sym(stats.amax[f"l{i}.gated"], method.a_bits))

        if method.quarot:
            # rotate the in_proj input space; scale absorbs 1/d
            H = hu.hadamard_np(cfg.d_model)
            w_in = H @ w_in
            ascales[p + "in_proj.weight.in_s"] = float(
                qc.scale_sym(stats.rot_amax[f"l{i}.resid_in"], method.a_bits))

        weights[p + "norm.weight"] = norm_w
        q, s = qc.quantize_weight_np(w_in, nb)
        weights[p + "in_proj.weight"] = q
        wscales[p + "in_proj.weight.s"] = float(s) / (cfg.d_model if method.quarot else 1)

        q, s = qc.quantize_weight_np(params[p + "conv1d.weight"], nb)
        weights[p + "conv1d.weight"] = q
        wscales[p + "conv1d.weight.s"] = float(s)
        weights[p + "conv1d.bias"] = params[p + "conv1d.bias"].astype(np.float32)

        q, s = qc.quantize_weight_np(params[p + "x_proj.weight"], nb)
        weights[p + "x_proj.weight"] = q
        wscales[p + "x_proj.weight.s"] = float(s)

        q, s = qc.quantize_weight_np(params[p + "dt_proj.weight"], nb)
        weights[p + "dt_proj.weight"] = q
        wscales[p + "dt_proj.weight.s"] = float(s)
        weights[p + "dt_proj.bias"] = params[p + "dt_proj.bias"].astype(np.float32)

        A = -np.exp(params[p + "A_log"].astype(np.float64)).astype(np.float32)
        q, s = qc.quantize_weight_np(A, nb)
        weights[p + "A_q"] = q
        wscales[p + "A_q.s"] = float(s)
        q, s = qc.quantize_weight_np(params[p + "D"], nb)
        weights[p + "D_q"] = q
        wscales[p + "D_q.s"] = float(s)

        if method.y_mode == "hadamard":
            H = hu.hadamard_np(cfg.d_inner)
            w_out = H @ w_out
        q, s = qc.quantize_weight_np(w_out, nb)
        weights[p + "out_proj.weight"] = q
        wscales[p + "out_proj.weight.s"] = float(s) / (cfg.d_inner if method.y_mode == "hadamard" else 1)

        # --- activation scales (per-site, per Eq. 2) ---
        ascales[p + "conv.in_s"] = float(qc.scale_sym(stats.amax[f"l{i}.conv_in"], method.a_bits))
        site = f"l{i}.x_ssm"
        ascales[f"l{i}.x_ssm.amax"] = stats.amax[site]
        if method.x_quant == "percentile":
            ascales[f"l{i}.x_ssm.s"] = float(
                qc.scale_sym(stats.percentile_amax(site, method.x_percentile), method.a_bits))
        else:
            ascales[f"l{i}.x_ssm.s"] = float(qc.scale_sym(stats.amax[site], method.a_bits))
        ascales[f"l{i}.x_ssm.asym"] = qc.asym_params(stats.vmin[site], stats.vmax[site], method.a_bits)
        if stats.rot_amax.get(site):
            ascales[f"l{i}.x_ssm.rot_s"] = float(qc.scale_sym(stats.rot_amax[site], method.a_bits))
        ascales[p + "x_proj.weight.in_s"] = ascales[f"l{i}.x_ssm.s"]
        ascales[p + "dt_proj.weight.in_s"] = float(qc.scale_sym(stats.amax[f"l{i}.dt_in"], method.a_bits))
        ascales[f"l{i}.B.s"] = float(qc.scale_sym(stats.amax[f"l{i}.B"], method.a_bits))
        ascales[f"l{i}.C.s"] = float(qc.scale_sym(stats.amax[f"l{i}.C"], method.a_bits))
        ascales[f"l{i}.gated_h.s"] = float(qc.scale_sym(stats.amax[f"l{i}.gated_h"], method.a_bits))

    weights["norm_f.weight"] = params["norm_f.weight"].astype(np.float32)
    q, s = qc.quantize_weight_np(params["embedding.weight"].T.copy(), nb)
    weights["lm_head.weight"] = q
    wscales["lm_head.weight.s"] = float(s)
    ascales["head.in_s"] = float(qc.scale_sym(stats.amax["head_in"], method.a_bits))

    return model_mod.QuantArtifacts(method, weights, wscales, ascales)


def quantized_model_bytes(weights) -> int:
    """Resident model bytes for the quantized parameter set (Table 1
    'Size' column analog)."""
    return sum(np.asarray(v).nbytes for v in weights.values())
