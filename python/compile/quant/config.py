"""Method descriptors: one entry per quantization recipe the paper
evaluates. A `Method` fully determines (a) how weights are transformed
and quantized offline, (b) which in-graph quantizers the model builder
inserts, and (c) which calibration statistics it needs.

Paper mapping:
  fp16            FP16 baseline (fp32 on this CPU testbed; documented)
  w8a8_static     "static"  naive per-tensor W8A8 (Table 2/3 `static`)
  w8a8_dynamic    "dynamic" scales recomputed in-graph (Table 2/3)
  smoothquant     SmQ-SSM re-implementation (alpha = 0.5)
  quarot          QuaRot-SSM re-implementation (W8A8)
  quamba          the paper's method: percentile-clipped SSM input +
                  fused Hadamard-quantized SSM output
  quamba_inper    ablation `+ In Per.`  (Table 5)
  quamba_outhad   ablation `+ Out Had.` (Table 5)
  quamba_p*       percentile sweep (Table 6)
  t9_*            SSM-input quantizer alternatives (Table 9)
  io_*            skip-quantize sensitivity variants (Figure 6)
  w4a4_quarot     low-bit QuaRot (Table 7/8)
  w2a16_quip      Quip#-like weight-only 2-bit (Table 7/8)
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class Method:
    name: str
    # weights
    w_bits: int = 8
    weight_only: bool = False          # W2A16: activations stay fp
    # activations
    a_bits: int = 8
    # SSM input x quantizer: minmax | percentile | dynamic | asym | log2 | fp
    x_quant: str = "minmax"
    x_percentile: float = 100.0
    # SSM output y treatment: none | hadamard | fp
    y_mode: str = "none"
    # non-SSM activation sites: static | dynamic
    act_mode: str = "static"
    # SmoothQuant folding on linear inputs (None = off)
    smooth_alpha: float | None = None
    # QuaRot-style rotations (input-path transforms + rotated linears)
    quarot: bool = False
    notes: str = ""

    @property
    def is_fp(self) -> bool:
        return self.name == "fp16"


def _m(name, **kw) -> Method:
    return Method(name=name, **kw)


METHODS = {
    m.name: m
    for m in [
        _m("fp16", notes="fp32 stand-in for FP16 on the CPU testbed"),
        _m("w8a8_static", x_quant="minmax", y_mode="none"),
        _m("w8a8_dynamic", x_quant="dynamic", y_mode="none", act_mode="dynamic"),
        _m("smoothquant", x_quant="minmax", smooth_alpha=0.5),
        _m("quarot", x_quant="minmax", y_mode="hadamard", quarot=True),
        _m("quamba", x_quant="percentile", x_percentile=99.999, y_mode="hadamard"),
        _m("quamba_inper", x_quant="percentile", x_percentile=99.999, y_mode="none"),
        _m("quamba_outhad", x_quant="minmax", y_mode="hadamard"),
        # Table 6 percentile sweep (99.999 == quamba itself)
        _m("quamba_p99", x_quant="percentile", x_percentile=99.0, y_mode="hadamard"),
        _m("quamba_p99_9", x_quant="percentile", x_percentile=99.9, y_mode="hadamard"),
        _m("quamba_p99_99", x_quant="percentile", x_percentile=99.99, y_mode="hadamard"),
        # Table 9: SSM-input quantizer alternatives (rest as Quamba)
        _m("t9_dyn", x_quant="dynamic", y_mode="hadamard"),
        _m("t9_asym", x_quant="asym", y_mode="hadamard"),
        _m("t9_log2", x_quant="log2", y_mode="hadamard"),
        # Figure 6: skip-quantize SSM I/O
        _m("io_fp_fp", x_quant="fp", y_mode="fp"),
        _m("io_i8_fp", x_quant="minmax", y_mode="fp"),
        _m("io_fp_i8", x_quant="fp", y_mode="none"),
        # low-bit (Tables 7/8)
        _m("w4a4_quarot", w_bits=4, a_bits=4, x_quant="minmax", y_mode="hadamard", quarot=True),
        _m("w2a16_quip", w_bits=2, weight_only=True, x_quant="fp", y_mode="fp"),
    ]
}

# Method groups used by aot.py to decide the artifact matrix.
CORE_METHODS = [
    "fp16", "w8a8_static", "w8a8_dynamic", "smoothquant", "quarot",
    "quamba", "quamba_inper", "quamba_outhad",
]
PERCENTILE_METHODS = ["quamba_p99", "quamba_p99_9", "quamba_p99_99"]
TABLE9_METHODS = ["t9_dyn", "t9_asym", "t9_log2"]
IO_METHODS = ["io_fp_fp", "io_i8_fp", "io_fp_i8"]
LOWBIT_METHODS = ["w4a4_quarot", "w2a16_quip"]
