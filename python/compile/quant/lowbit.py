"""Low-bit baselines (paper §E, Tables 7/8).

* W2A16 "Quip#-like": weight-only 2-bit with Hadamard incoherence
  processing — W is rotated (H_in W), quantized per-channel at 2 bits,
  then de-rotated offline, so the deployment graph is a plain fp
  forward over the (heavily) degraded weights. Rotation happens purely
  offline for weight-only quantization, which is exactly why Quip#
  carries no runtime transform cost.
* W4A4 QuaRot reuses the `quarot` graph with 4-bit clamps (see
  quant.config.w4a4_quarot); nothing extra lives here.
"""

from __future__ import annotations

from collections import OrderedDict

import numpy as np

from .. import model as model_mod
from . import core as qc
from . import hadamard_util as hu


def _incoherent_quant(w: np.ndarray, nbits: int) -> np.ndarray:
    """Rotate → per-channel quantize → de-rotate (all offline)."""
    n = w.shape[0]
    try:
        H = hu.hadamard_np(n)
    except ValueError:
        H = None
    wr = (H @ w) if H is not None else w
    q, s = qc.quantize_weight_perchannel_np(wr, axis=1, nbits=nbits)
    wq = q.astype(np.float32) * s
    return ((H.T @ wq) / n).astype(np.float32) if H is not None else wq.astype(np.float32)


def build_weight_only(cfg, params, method):
    """QuantArtifacts for the W2A16 path: weights stored as int8 codes +
    per-channel scales; activations untouched. 1-D parameters (biases,
    norms, D) and the embedding stay fp — matching weight-only practice
    of quantizing only the big matrices."""
    weights: "OrderedDict[str, np.ndarray]" = OrderedDict()
    wscales: dict = {}
    for name, w in params.items():
        if w.ndim == 2 and "embedding" not in name and "A_log" not in name:
            n = w.shape[0]
            try:
                H = hu.hadamard_np(n)
            except ValueError:
                H = None
            wr = (H @ w) if H is not None else np.asarray(w, np.float32)
            q, s = qc.quantize_weight_perchannel_np(wr, axis=1, nbits=method.w_bits)
            wq = q.astype(np.float32) * s
            deq = ((H.T @ wq) / n).astype(np.float32) if H is not None else wq.astype(np.float32)
            # store the dequantized-derotated weight as the runtime param
            # (weight-only: the graph consumes fp weights; the 4x memory
            # saving is accounted analytically in the size table)
            weights[name + ".q"] = np.clip(np.round(deq / max(1e-8, np.abs(deq).max() / 127)),
                                           -127, 127).astype(np.int8)
            weights[name + ".q.s"] = np.full((1,), np.abs(deq).max() / 127, np.float32)
        else:
            weights[name] = np.asarray(w, np.float32)
    return model_mod.QuantArtifacts(method, weights, wscales, {})
