"""Core quantization primitives (numpy for offline, jnp for in-graph).

Everything here implements Eq. 2 of the paper and its variants:

    x_q = clamp(round(x / s), -2^{N-1}, 2^{N-1}-1),  s = amax / (2^{N-1}-1)

Static scales are *pre-calibrated* floats; the graph bakes them as
constants (per-tensor symmetric, matching the paper's deployment
setting, CUTLASS-compatible). The alternatives explored in paper
Table 9 — dynamic, asymmetric and log2 quantization — are implemented
here as well so the Table 9 bench can regenerate the comparison.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def qmax(nbits: int) -> int:
    return 2 ** (nbits - 1) - 1


def qmin(nbits: int) -> int:
    return -(2 ** (nbits - 1))


def scale_sym(amax, nbits: int = 8):
    """Symmetric scale from an absolute max (avoids zero scales)."""
    amax = np.maximum(np.asarray(amax, dtype=np.float64), 1e-8)
    return (amax / qmax(nbits)).astype(np.float32)


def percentile_amax(x: np.ndarray, p: float) -> float:
    """The paper's percentile max: the p-th percentile of |x| (p in %,
    e.g. 99.999). p=100 reduces to the plain abs-max."""
    ax = np.abs(np.asarray(x, dtype=np.float32)).reshape(-1)
    if p >= 100.0:
        return float(ax.max(initial=0.0))
    return float(np.percentile(ax, p))


# --- in-graph (jnp) ---------------------------------------------------------

def quantize_sym(x, s, nbits: int = 8, dtype=jnp.int8):
    """Quantize to signed integers with a static scale (jnp)."""
    q = jnp.clip(jnp.round(x / s), qmin(nbits), qmax(nbits))
    return q.astype(dtype)


def dequantize_sym(q, s):
    return q.astype(jnp.float32) * s


def fake_quant_sym(x, s, nbits: int = 8):
    """Quantize-dequantize round trip (used for sites where the next op
    consumes floats, and for the low-bit ablations)."""
    return dequantize_sym(quantize_sym(x, s, nbits, dtype=jnp.int32), s)


def dynamic_fake_quant(x, nbits: int = 8):
    """Dynamic per-tensor quantization: the scale is recomputed from the
    live tensor inside the graph (paper's `dynamic` baseline; accurate
    but adds a reduction + host-side scale churn on real HW)."""
    s = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / qmax(nbits)
    return fake_quant_sym(x, s, nbits), s


def quantize_asym(x, s, z, nbits: int = 8):
    """Asymmetric: x_q = clamp(round(x/s)+z). (Table 9 `MinMax Asym.`)"""
    lo, hi = 0, 2**nbits - 1
    q = jnp.clip(jnp.round(x / s) + z, lo, hi)
    return q.astype(jnp.int32)


def dequantize_asym(q, s, z):
    return (q.astype(jnp.float32) - z) * s


def fake_quant_asym(x, s, z, nbits: int = 8):
    return dequantize_asym(quantize_asym(x, s, z, nbits), s, z)


def asym_params(xmin: float, xmax: float, nbits: int = 8):
    """Offline computation of (s, zero_point) from observed min/max."""
    xmin, xmax = min(xmin, 0.0), max(xmax, 0.0)
    s = max((xmax - xmin), 1e-8) / (2**nbits - 1)
    z = round(-xmin / s)
    return np.float32(s), np.int32(z)


def fake_quant_log2(x, s, nbits: int = 8):
    """Log2 quantization (Table 9): values map to +/- s * 2^e with e an
    integer exponent code; preserves small magnitudes that uniform
    quantization crushes when the scale is outlier-skewed."""
    sign = jnp.sign(x)
    mag = jnp.abs(x) / s
    # exponent codes: 0 encodes zero, 1..2^{N-1}-1 encode 2^{e_min+k}
    e = jnp.round(jnp.log2(jnp.maximum(mag, 1e-12)))
    levels = 2 ** (nbits - 1) - 1
    e = jnp.clip(e, -levels + 1, 0.0)  # mag <= 1 after amax scaling
    out = sign * (2.0**e) * s
    return jnp.where(jnp.abs(x) < s * 2.0 ** (-levels + 1) * 0.5, 0.0, out)


# --- offline (numpy) weight quantization ------------------------------------

def quantize_weight_np(w: np.ndarray, nbits: int = 8):
    """Per-tensor symmetric weight quantization; returns (w_q, s)."""
    s = scale_sym(np.abs(w).max(initial=0.0), nbits)
    q = np.clip(np.round(w / s), qmin(nbits), qmax(nbits))
    dtype = np.int8 if nbits <= 8 else np.int32
    return q.astype(dtype), np.float32(s)


def quantize_weight_perchannel_np(w: np.ndarray, axis: int, nbits: int = 8):
    """Per-channel symmetric (used by the W2A16 Quip#-like baseline)."""
    amax = np.abs(w).max(axis=tuple(i for i in range(w.ndim) if i != axis), keepdims=True)
    s = np.maximum(amax, 1e-8) / qmax(nbits)
    q = np.clip(np.round(w / s), qmin(nbits), qmax(nbits)).astype(np.int8)
    return q, s.astype(np.float32)
