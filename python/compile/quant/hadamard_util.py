"""Walsh-Hadamard transform utilities (paper §3.3, §4.2).

For n = 2^k the fast Walsh-Hadamard transform (FWHT) applies log n
butterfly stages of additions/subtractions — no multiplies. For
n != 2^k the paper factorizes n = 2^p * m where m is the size of a
known Hadamard matrix (Sloane's library); we construct H_12 and H_20
with the Paley type-I construction (q prime, q ≡ 3 mod 4 → H_{q+1}),
which covers every d_inner in our model tiers:

    128 = 2^7            192 = 2^6 * 12 / 4 -> 16 * 12
    256 = 2^8            320 = 16 * 20

Conventions: `hadamard(n)` returns the *unnormalized* +/-1 matrix H_n
with H_n @ H_n.T = n I. The compute-invariant fusion in the model uses
W_out' = H W_out and y' = H y with a 1/n correction folded into the
output scale (paper §4.2).
"""

from __future__ import annotations

from functools import lru_cache

import jax.numpy as jnp
import numpy as np


def _legendre(a: int, q: int) -> int:
    """Legendre symbol (a/q) for odd prime q."""
    a %= q
    if a == 0:
        return 0
    r = pow(a, (q - 1) // 2, q)
    return 1 if r == 1 else -1


@lru_cache(maxsize=None)
def paley_hadamard(q: int) -> np.ndarray:
    """Paley construction I: for prime q ≡ 3 (mod 4), builds H_{q+1}."""
    if q % 4 != 3:
        raise ValueError("Paley-I needs q ≡ 3 (mod 4)")
    n = q + 1
    # Jacobsthal matrix Q_{ij} = legendre(j - i); H = I + S with the
    # skew core S = [[0, 1],[−1, Q]] (type-I construction)
    Q = np.empty((q, q), dtype=np.int64)
    for i in range(q):
        for j in range(q):
            Q[i, j] = _legendre(j - i, q)
    H = np.ones((n, n), dtype=np.int64)
    H[1:, 1:] = Q + np.eye(q, dtype=np.int64)
    H[1:, 0] = -1
    assert (H @ H.T == n * np.eye(n, dtype=np.int64)).all()
    return H


@lru_cache(maxsize=None)
def hadamard(n: int) -> np.ndarray:
    """Hadamard matrix of size n (n = 2^p * m, m in {1, 12, 20})."""
    if n == 1:
        return np.array([[1]], dtype=np.int64)
    if n == 12:
        return paley_hadamard(11)
    if n == 20:
        return paley_hadamard(19)
    if n % 2 == 0:
        h = hadamard(n // 2)
        return np.block([[h, h], [h, -h]])
    raise ValueError(f"no Hadamard construction for n={n}")


def decompose(n: int):
    """Factor n = 2^p * m with m in {1, 12, 20}; returns (p, m)."""
    p = 0
    while n % 2 == 0:
        n //= 2
        p += 1
    if n in (1, 12 >> 2, 20 >> 2):  # pragma: no cover - unreachable guard
        pass
    if n == 1:
        return p, 1
    if n in (3, 5):
        # 12 = 4*3, 20 = 4*5: move two powers of two into the base matrix
        if p < 2:
            raise ValueError(f"cannot factorize {n << p} into 2^p * (12|20)")
        return p - 2, n * 4
    raise ValueError(f"cannot factorize Hadamard size with odd part {n}")


def fwht(x: np.ndarray) -> np.ndarray:
    """In-place-style FWHT over the last axis (n = 2^p * m). Returns
    H_n @ x along the last dim, unnormalized. numpy reference."""
    n = x.shape[-1]
    p, m = decompose(n)
    y = np.asarray(x, dtype=np.float64).copy()
    shape = y.shape
    y = y.reshape(-1, n)
    if m > 1:
        hm = hadamard(m).astype(np.float64)
        y = y.reshape(-1, 2**p, m) @ hm.T
        y = y.reshape(-1, n)
    h = 1
    while h < 2**p:
        y = y.reshape(-1, 2**p // (2 * h), 2, h * m)
        a = y[:, :, 0, :].copy()
        b = y[:, :, 1, :].copy()
        y[:, :, 0, :] = a + b
        y[:, :, 1, :] = a - b
        y = y.reshape(-1, n)
        h *= 2
    return y.reshape(shape).astype(x.dtype if np.issubdtype(x.dtype, np.floating) else np.float64)


def fwht_jnp(x, n: int | None = None):
    """FWHT over the last axis in jnp (structured as the log-n butterfly
    the Pallas kernel mirrors — O(n log n) adds, zero multiplies)."""
    n = n or x.shape[-1]
    p, m = decompose(n)
    shape = x.shape
    y = x.reshape((-1, n))
    if m > 1:
        hm = jnp.asarray(hadamard(m), dtype=x.dtype)
        y = y.reshape(-1, 2**p, m) @ hm.T
        y = y.reshape(-1, n)
    h = 1
    while h < 2**p:
        y = y.reshape(-1, 2**p // (2 * h), 2, h * m)
        a = y[:, :, 0, :]
        b = y[:, :, 1, :]
        y = jnp.stack([a + b, a - b], axis=2)
        y = y.reshape(-1, n)
        h *= 2
    return y.reshape(shape)


def ifwht_jnp(y, n: int | None = None):
    """Inverse transform x = (1/n)·Hᵀy over the last axis. For pure
    2^k sizes H is symmetric and this equals fwht/n, but the Paley
    bases (H12, H20) are NOT symmetric — the base contraction must use
    H_mᵀ. (Getting this wrong silently corrupts every d ∈ {96, 160,
    192, 320} path; regression-tested in test_hadamard.py.)"""
    n = n or y.shape[-1]
    p, m = decompose(n)
    shape = y.shape
    v = y.reshape((-1, n))
    # butterfly stages are symmetric and mutually commuting
    h = 1
    while h < 2**p:
        v = v.reshape(-1, 2**p // (2 * h), 2, h * m)
        a = v[:, :, 0, :]
        b = v[:, :, 1, :]
        v = jnp.stack([a + b, a - b], axis=2)
        v = v.reshape(-1, n)
        h *= 2
    if m > 1:
        hm = jnp.asarray(hadamard(m), dtype=y.dtype)
        v = v.reshape(-1, 2**p, m) @ hm      # r @ H_m == H_mᵀ r
        v = v.reshape(-1, n)
    return v.reshape(shape) / n


def hadamard_np(n: int) -> np.ndarray:
    return hadamard(n).astype(np.float32)
