"""QuaRot-SSM (paper §C): rotation-based outlier suppression
re-implemented for the Mamba architecture.

Three rotations are used, mirroring the paper's Figure 7(b):

  1. block input  : x̄ = Q(H_d · norm(x)); H_d folded into in_proj
                    offline (compute-invariant, exact);
  2. SSM input x  : online rotate → quantize → de-rotate. The scan is
                    channel-diagonal, so the rotation CANNOT be folded —
                    this is precisely the "extra transpose and Hadamard
                    transforms" overhead the paper charges QuaRot-SSM
                    with (Table 1);
  3. SSM output   : identical to Quamba's fused Hadamard-quantize with
                    H folded into out_proj.

The offline folds live in quant.calibrate.build_artifacts; this module
keeps the standalone helpers + the W4A4 variant knobs.
"""

from __future__ import annotations

import numpy as np

from . import hadamard_util as hu


def rotate_in_proj(w_in: np.ndarray, d_model: int) -> np.ndarray:
    """W' = H_d · W_in; pair with x' = H_d x and a 1/d factor in the
    dequant scale."""
    return (hu.hadamard_np(d_model) @ w_in).astype(np.float32)


def rotate_out_proj(w_out: np.ndarray, d_inner: int) -> np.ndarray:
    """W' = H_di · W_out; pair with y' = H_di y and 1/d_inner."""
    return (hu.hadamard_np(d_inner) @ w_out).astype(np.float32)


def online_rotation_cost(d_inner: int, T: int) -> int:
    """Extra adds QuaRot-SSM spends per block on the x path (the cost
    Quamba avoids): two FWHTs + a transpose ≈ 2·T·d·log2(d) adds."""
    import math

    return int(2 * T * d_inner * math.log2(d_inner))
