"""LLM.int8-style mixed-precision decomposition (Dettmers et al. 2022),
used by the Jamba hybrid experiments (paper Table 4).

Columns of the input whose calibrated per-channel amax exceeds a
threshold are kept in fp and matmul'ed separately; the rest go through
the int8 path:

    y = X[:, O] @ W[O, :]  (fp)  +  Q(X[:, R]) @ Q(W[R, :])  (int8)

The outlier set O is chosen offline from calibration stats (static,
like the rest of our pipeline; the original does it dynamically, which
only grows O over batches — the static set is its fixed-point on the
calibration distribution).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import core as qc


def outlier_columns(chan_amax: np.ndarray, threshold: float = 6.0) -> np.ndarray:
    """LLM.int8's magnitude criterion: columns with amax above
    `threshold` (in units of the median channel amax) are outliers."""
    med = max(1e-8, float(np.median(chan_amax)))
    return np.where(chan_amax > threshold * med)[0].astype(np.int32)


def split_weight(w: np.ndarray, outliers: np.ndarray, nbits: int = 8):
    """Split W (K, N) into the fp outlier rows and the quantized rest.
    Returns dict of arrays for the artifact bundle."""
    mask = np.zeros(w.shape[0], dtype=bool)
    mask[outliers] = True
    w_o = w[mask].astype(np.float32)                  # (|O|, N)
    q, s = qc.quantize_weight_np(w[~mask], nbits)     # (K-|O|, N) int8
    return {
        "outlier_idx": outliers,
        "w_outlier": w_o,
        "w_q": q,
        "w_s": np.float32(s),
        "keep_idx": np.where(~mask)[0].astype(np.int32),
    }


def matmul_mixed(x, parts, s_x_rest: float, nbits: int = 8):
    """y = x[:, O] @ W_O + Q(x[:, R]) @ W_R_q (jnp, in-graph)."""
    o_idx = jnp.asarray(parts["outlier_idx"])
    k_idx = jnp.asarray(parts["keep_idx"])
    x_o = jnp.take(x, o_idx, axis=-1)
    x_r = jnp.take(x, k_idx, axis=-1)
    y_fp = x_o @ parts["w_outlier"] if parts["w_outlier"].shape[0] else 0.0
    x_q = qc.quantize_sym(x_r, s_x_rest, nbits)
    acc = jax.lax.dot_general(
        x_q, parts["w_q"], (((x_q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    y_q = acc.astype(jnp.float32) * (s_x_rest * float(parts["w_s"]))
    return y_fp + y_q
