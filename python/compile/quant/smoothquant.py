"""SmoothQuant-SSM (paper §5.1 baselines): α-balanced rescaling between
activations and weights, re-implemented for the Mamba architecture.

The migration identity (Xiao et al. 2023):  X W = (X · diag(s)^-1)(diag(s) W)
with s_j = amax(X_j)^α / amax(W_j)^{1-α}. For Mamba we fold:

  * in_proj  : the activation divide folds into the preceding RMSNorm
               weight — exact and free;
  * out_proj : the input is the gated SSM output (no producer weight to
               fold into), so the divide stays in-graph as one
               elementwise multiply by a baked 1/s vector — this is the
               cost profile the paper describes for SmQ-SSM.
  * x_proj / dt_proj : unsmoothed (their input is the percentile-less
               conv output; smoothing through the SiLU is not exact —
               DESIGN.md §4 documents the simplification).

The folds themselves are applied in quant.calibrate.build_artifacts;
this module hosts the vector computation so it can be unit-tested and
reused by the Jamba mixed pipeline.
"""

from __future__ import annotations

import numpy as np


def smooth_vector(act_chan_amax: np.ndarray, w_chan_amax: np.ndarray,
                  alpha: float = 0.5, clip: float = 1e2) -> np.ndarray:
    """Per-input-channel migration factors s (clipped for stability)."""
    s = np.power(np.maximum(act_chan_amax, 1e-5), alpha) / np.power(
        np.maximum(w_chan_amax, 1e-5), 1.0 - alpha
    )
    return np.clip(s, 1.0 / clip, clip).astype(np.float32)


def fold_linear(act_chan_amax: np.ndarray, w: np.ndarray, alpha: float = 0.5):
    """Return (s, w_folded): w_folded = diag(s) @ w. The caller is
    responsible for dividing the activation (or the producer weight)
    by s."""
    s = smooth_vector(act_chan_amax, np.abs(w).max(axis=1), alpha)
    return s, (w * s[:, None]).astype(np.float32)
