"""L2: the Mamba language model in JAX, with every quantization-method
variant folded into the traced graph.

Two forward paths exist:

* :func:`forward_fp` — the pure-jnp fp32 reference. Used for training,
  calibration (``collect=True`` returns every interesting activation),
  and as the "FP16" baseline graph. No Pallas.
* :func:`forward_q` — the quantized deployment graph. Calls the Pallas
  kernels (int8 GEMMs, fused conv/norm/Hadamard, quantized selective
  scan) with static scales baked in; weights arrive as *runtime
  parameters* (int8 for W8A8 sites) so the rust runtime feeds them once
  as device buffers and reports true int8 resident bytes.

Both paths share the parameter naming scheme (`layers.{i}.<leaf>`) and
are cross-checked in `python/tests/test_model.py`.

State layout (shared with the rust coordinator):
  conv_state : (L, B, W-1, d_inner) f32 — causal-conv window tail
  ssm_state  : (L, B, d_inner, N)   f32 — recurrent SSM state
Prefill and decode both consume and produce the pair, so the rust side
can chain prefill → decode and chunk long sequences. States are f32
for every method (quantized methods store the *dequantized* conv
window — exactly representable, so the int8 conv math is preserved).
"""

from __future__ import annotations

import math
from collections import OrderedDict
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from .kernels import ref
from .kernels.causal_conv import causal_conv_silu_q_pallas
from .kernels.hadamard import hadamard_quant_pallas
from .kernels.matmul_i8 import matmul_i8_pallas
from .kernels.rmsnorm import rmsnorm_resid_q_pallas
from .kernels.selective_scan import selective_scan_pallas, selective_scan_q_pallas
from .quant import core as qc
from .quant import hadamard_util as hu
from .quant.config import Method


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TierConfig:
    """A scaled-down analog of one paper model size (DESIGN.md §2)."""

    name: str
    paper_name: str
    d_model: int
    n_layer: int
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    vocab: int = data_mod.VOCAB_SIZE
    eps: float = 1e-5

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(1, math.ceil(self.d_model / 16))

    def n_params(self) -> int:
        d, di, r, n, w, v = (self.d_model, self.d_inner, self.dt_rank,
                             self.d_state, self.d_conv, self.vocab)
        per_layer = (d + d * 2 * di + w * di + di + di * (r + 2 * n)
                     + r * di + di + di * n + di + di * d)
        return v * d + d + self.n_layer * per_layer


TIERS = OrderedDict(
    (t.name, t)
    for t in [
        TierConfig("m130", "Mamba-130M", d_model=64, n_layer=2),
        TierConfig("m370", "Mamba-370M", d_model=96, n_layer=3),
        TierConfig("m1p4", "Mamba-1.4B", d_model=128, n_layer=4),
        TierConfig("m2p8", "Mamba-2.8B", d_model=160, n_layer=5),
    ]
)


def layer_param_names(i: int) -> list:
    p = f"layers.{i}."
    return [
        p + "norm.weight",
        p + "in_proj.weight",
        p + "conv1d.weight",
        p + "conv1d.bias",
        p + "x_proj.weight",
        p + "dt_proj.weight",
        p + "dt_proj.bias",
        p + "A_log",
        p + "D",
        p + "out_proj.weight",
    ]


def param_names(cfg: TierConfig) -> list:
    names = ["embedding.weight"]
    for i in range(cfg.n_layer):
        names += layer_param_names(i)
    names += ["norm_f.weight"]
    return names


def init_params(cfg: TierConfig, seed: int = 0) -> "OrderedDict[str, np.ndarray]":
    """Mamba-style initialization (S4D-real A, dt bias softplus-inverse
    log-uniform in [1e-3, 1e-1], fan-in scaled projections)."""
    rng = np.random.default_rng(seed)
    d, di, r, n, w = cfg.d_model, cfg.d_inner, cfg.dt_rank, cfg.d_state, cfg.d_conv
    params: "OrderedDict[str, np.ndarray]" = OrderedDict()

    def dense(shape, scale=None):
        s = scale if scale is not None else 1.0 / math.sqrt(shape[0])
        return rng.uniform(-s, s, size=shape).astype(np.float32)

    params["embedding.weight"] = rng.normal(0, 0.02, size=(cfg.vocab, d)).astype(np.float32)
    for i in range(cfg.n_layer):
        p = f"layers.{i}."
        params[p + "norm.weight"] = np.ones(d, np.float32)
        params[p + "in_proj.weight"] = dense((d, 2 * di))
        params[p + "conv1d.weight"] = dense((w, di), scale=1.0 / math.sqrt(w))
        params[p + "conv1d.bias"] = np.zeros(di, np.float32)
        params[p + "x_proj.weight"] = dense((di, r + 2 * n))
        params[p + "dt_proj.weight"] = dense((r, di), scale=r**-0.5)
        # dt bias: softplus^{-1}(dt) with dt ~ logUniform[1e-3, 1e-1]
        dt = np.exp(rng.uniform(math.log(1e-3), math.log(1e-1), size=di))
        params[p + "dt_proj.bias"] = (dt + np.log(-np.expm1(-dt))).astype(np.float32)
        # S4D-real: A = -(1..n) per channel
        params[p + "A_log"] = np.log(np.tile(np.arange(1, n + 1, dtype=np.float32), (di, 1)))
        params[p + "D"] = np.ones(di, np.float32)
        params[p + "out_proj.weight"] = dense((di, d))
    params["norm_f.weight"] = np.ones(d, np.float32)
    return params


# ---------------------------------------------------------------------------
# fp32 reference forward (training / calibration / FP16 baseline)
# ---------------------------------------------------------------------------

def zero_states(cfg: TierConfig, batch: int):
    conv = jnp.zeros((cfg.n_layer, batch, cfg.d_conv - 1, cfg.d_inner), jnp.float32)
    ssm = jnp.zeros((cfg.n_layer, batch, cfg.d_inner, cfg.d_state), jnp.float32)
    return conv, ssm


def _conv_fp(x, conv_st, w, bias):
    """f32 causal conv over the window [conv_st ; x] + SiLU.
    Returns (activated, new_conv_state)."""
    W = w.shape[0]
    T = x.shape[1]
    full = jnp.concatenate([conv_st, x], axis=1)        # (B, W-1+T, di)
    conv = sum(full[:, j : j + T, :] * w[j][None, None, :] for j in range(W))
    return ref.silu(conv + bias[None, None, :]), full[:, -(W - 1):, :]


def _block_fp(cfg: TierConfig, params, i: int, x_in, conv_st, ssm_st, taps=None, gains=None):
    """One Mamba block, fp32. x_in: (B, T, d) post-norm. `gains` is an
    optional (g_x, g_y) pair of (L, d_inner) fixed diagonal maps — the
    outlier-injection mechanism (DESIGN.md §5), part of the model
    definition and identical across fp/quantized paths."""
    p = f"layers.{i}."
    di, n, r = cfg.d_inner, cfg.d_state, cfg.dt_rank
    xz = x_in @ params[p + "in_proj.weight"]            # (B,T,2di)
    x, z = xz[..., :di], xz[..., di:]
    if taps is not None:
        taps[f"l{i}.conv_in"] = x
    x_ssm, new_conv = _conv_fp(x, conv_st, params[p + "conv1d.weight"], params[p + "conv1d.bias"])
    if gains is not None:
        x_ssm = x_ssm * gains[0][i][None, None, :]
    if taps is not None:
        taps[f"l{i}.x_ssm"] = x_ssm
    bcdt = x_ssm @ params[p + "x_proj.weight"]          # (B,T,r+2n)
    dt_low, B_, C_ = bcdt[..., :r], bcdt[..., r : r + n], bcdt[..., r + n :]
    dt = ref.softplus(dt_low @ params[p + "dt_proj.weight"] + params[p + "dt_proj.bias"])
    if taps is not None:
        taps[f"l{i}.dt_in"] = dt_low
        taps[f"l{i}.B"] = B_
        taps[f"l{i}.C"] = C_
    A = -jnp.exp(params[p + "A_log"])
    y, hT = ref.selective_scan(x_ssm, dt, A, B_, C_, params[p + "D"], h0=ssm_st)
    if taps is not None:
        taps[f"l{i}.y"] = y
    gated = y * ref.silu(z)
    if gains is not None:
        gated = gated * gains[1][i][None, None, :]
    if taps is not None:
        taps[f"l{i}.gated"] = gated
        taps[f"l{i}.gated_h"] = hu.fwht_jnp(gated)
    out = gated @ params[p + "out_proj.weight"]
    return out, new_conv, hT


def forward_fp(cfg: TierConfig, params, tokens, conv_state=None, ssm_state=None, collect=False,
               gains=None):
    """fp32 forward. tokens: (B, T) int32.
    Returns (logits, conv_state', ssm_state'[, taps])."""
    B, T = tokens.shape
    if conv_state is None:
        conv_state, ssm_state = zero_states(cfg, B)
    taps = OrderedDict() if collect else None
    resid = params["embedding.weight"][tokens]          # (B,T,d)
    new_conv, new_ssm = [], []
    for i in range(cfg.n_layer):
        x_in = ref.rmsnorm(resid, params[f"layers.{i}.norm.weight"], cfg.eps)
        if taps is not None:
            taps[f"l{i}.resid_in"] = x_in
        out, c, s = _block_fp(cfg, params, i, x_in, conv_state[i], ssm_state[i], taps, gains)
        resid = resid + out
        new_conv.append(c)
        new_ssm.append(s)
    final = ref.rmsnorm(resid, params["norm_f.weight"], cfg.eps)
    if taps is not None:
        taps["head_in"] = final
    logits = final @ params["embedding.weight"].T
    out = (logits, jnp.stack(new_conv), jnp.stack(new_ssm))
    return out + (taps,) if collect else out


# ---------------------------------------------------------------------------
# Quantized deployment graphs
# ---------------------------------------------------------------------------
#
# A `QuantArtifacts` bundle (produced by quant.calibrate + quantize_weights)
# carries:
#   weights : OrderedDict[str, np.ndarray] — runtime parameters (int8 for
#             W8A8 sites; f32 for norm weights, biases, embedding; folds
#             such as W_out^H = H·W_out or SmoothQuant diag(s)·W already
#             applied offline)
#   wscales : dict[str, float]     per-tensor weight scales (baked)
#   ascales : dict[str, ...]       per-site activation scales (baked)
#   method  : Method


class QuantArtifacts:
    def __init__(self, method: Method, weights, wscales, ascales):
        self.method = method
        self.weights = weights
        self.wscales = wscales
        self.ascales = ascales


def _mm(x8, w, s_x, s_w, use_pallas, bias=None):
    if use_pallas:
        return matmul_i8_pallas(x8, w, s_x, s_w, bias)
    return ref.matmul_i8(x8, w, s_x, s_w, bias)


def _block_q(cfg: TierConfig, qa: QuantArtifacts, weights, i: int, x8, conv_st, ssm_st,
             use_pallas: bool, fresh_state: bool, gains=None):
    """One quantized Mamba block. x8: int8 (B,T,d) from the fused norm.
    `fresh_state` marks a from-zero prefill, enabling the fully fused
    int8 conv kernel (whose causal padding is zeros)."""
    m = qa.method
    p = f"layers.{i}."
    di, n, r, W = cfg.d_inner, cfg.d_state, cfg.dt_rank, cfg.d_conv
    T = x8.shape[1]
    asc, wsc = qa.ascales, qa.wscales
    x_mode = "quarot" if m.quarot else m.x_quant
    g_x = None if gains is None else gains[0][i]
    g_y = None if gains is None else gains[1][i]

    # -- in_proj (W8A8) --
    s_in = asc[p + "in_proj.weight.in_s"]
    xz = _mm(x8, weights[p + "in_proj.weight"], s_in, wsc[p + "in_proj.weight.s"], use_pallas)
    x, z = xz[..., :di], xz[..., di:]

    # -- causal conv + SiLU + x-site quantizer --
    s_cin = asc[p + "conv.in_s"]
    x8c = qc.quantize_sym(x, s_cin, m.a_bits)
    x_deq = qc.dequantize_sym(x8c, s_cin)
    new_conv = jnp.concatenate([conv_st, x_deq], axis=1)[:, -(W - 1):, :]
    w_conv = weights[p + "conv1d.weight"]               # int8 (W, di)
    s_wc = wsc[p + "conv1d.weight.s"]
    bias = weights[p + "conv1d.bias"]                   # f32

    x_i8 = None                                          # (x_q, s_x) when the scan runs int8
    if x_mode in ("minmax", "percentile"):
        s_x = asc[f"l{i}.x_ssm.s"]
        if fresh_state and T > 1 and use_pallas:
            # fully fused int8 path (paper §4.3): conv+SiLU+requant
            x8s = causal_conv_silu_q_pallas(x8c, s_cin, w_conv, s_wc, bias, s_x, m.a_bits, gain=g_x)
        else:
            x8s = ref.causal_conv_silu_q(x8c, s_cin, w_conv, s_wc, bias, s_x, m.a_bits, gain=g_x) \
                if fresh_state else _conv_live_q(x_deq, conv_st, w_conv, s_wc, bias, s_x,
                                                 m.a_bits, gain=g_x)
        x_i8 = (x8s, s_x)
        x_ssm_f = qc.dequantize_sym(x8s, s_x)
    else:
        # general path: f32 conv over [state ; x], then the x-site mode
        w_deq = w_conv.astype(jnp.float32) * s_wc
        full = jnp.concatenate([conv_st, x_deq], axis=1)
        conv = sum(full[:, j : j + T, :] * w_deq[j][None, None, :] for j in range(W))
        x_ssm_f = ref.silu(conv + bias[None, None, :])
        if g_x is not None:
            x_ssm_f = x_ssm_f * g_x[None, None, :]
        if x_mode == "fp":
            pass
        elif x_mode == "dynamic":
            x_ssm_f, _ = qc.dynamic_fake_quant(x_ssm_f, m.a_bits)
        elif x_mode == "asym":
            s, zp = asc[f"l{i}.x_ssm.asym"]
            x_ssm_f = qc.fake_quant_asym(x_ssm_f, s, zp, m.a_bits)
        elif x_mode == "log2":
            x_ssm_f = qc.fake_quant_log2(x_ssm_f, asc[f"l{i}.x_ssm.amax"], m.a_bits)
        elif x_mode == "quarot":
            # rotate channels, quantize outlier-free, rotate back (the
            # extra transforms the paper charges QuaRot-SSM for);
            # inverse must be (1/n)Hᵀ — Paley bases are not symmetric
            xr = hu.fwht_jnp(x_ssm_f)
            xr = qc.fake_quant_sym(xr, asc[f"l{i}.x_ssm.rot_s"], m.a_bits)
            x_ssm_f = hu.ifwht_jnp(xr)

    # -- selection projections (W8A8 off the quantized x) --
    if x_i8 is not None:
        xq_proj, s_xp = x_i8
    else:
        s_xp = asc[p + "x_proj.weight.in_s"]
        xq_proj = qc.quantize_sym(x_ssm_f, s_xp, m.a_bits)
    bcdt = _mm(xq_proj, weights[p + "x_proj.weight"], s_xp, wsc[p + "x_proj.weight.s"], use_pallas)
    dt_low, B_f, C_f = bcdt[..., :r], bcdt[..., r : r + n], bcdt[..., r + n :]
    s_dt = asc[p + "dt_proj.weight.in_s"]
    dt8 = qc.quantize_sym(dt_low, s_dt, m.a_bits)
    dt = ref.softplus(
        _mm(dt8, weights[p + "dt_proj.weight"], s_dt, wsc[p + "dt_proj.weight.s"], use_pallas,
            bias=weights[p + "dt_proj.bias"])
    )

    # -- selective scan (int8 fast path or fp fallback) --
    A_q, D_q = weights[p + "A_q"], weights[p + "D_q"]
    s_A, s_D = wsc[p + "A_q.s"], wsc[p + "D_q.s"]
    if x_i8 is not None and m.a_bits == 8:
        s_B, s_C = asc[f"l{i}.B.s"], asc[f"l{i}.C.s"]
        B8 = qc.quantize_sym(B_f, s_B, m.a_bits)
        C8 = qc.quantize_sym(C_f, s_C, m.a_bits)
        scan = selective_scan_q_pallas if use_pallas else ref.selective_scan_q
        y, hT = scan(x_i8[0], x_i8[1], dt, A_q, s_A, B8, s_B, C8, s_C, D_q, s_D, h0=ssm_st)
    else:
        A = qc.dequantize_sym(A_q, s_A)
        D = qc.dequantize_sym(D_q, s_D)
        if m.act_mode == "dynamic":
            B_f, _ = qc.dynamic_fake_quant(B_f, m.a_bits)
            C_f, _ = qc.dynamic_fake_quant(C_f, m.a_bits)
        elif x_mode != "fp" or m.a_bits < 8:
            B_f = qc.fake_quant_sym(B_f, asc[f"l{i}.B.s"], m.a_bits)
            C_f = qc.fake_quant_sym(C_f, asc[f"l{i}.C.s"], m.a_bits)
        scan = selective_scan_pallas if use_pallas else ref.selective_scan
        y, hT = scan(x_ssm_f, dt, A, B_f, C_f, D, h0=ssm_st)

    # -- gate + output projection --
    gated = y * ref.silu(z)
    if g_y is not None:
        gated = gated * g_y[None, None, :]
    w_out = weights[p + "out_proj.weight"]
    s_wo = wsc[p + "out_proj.weight.s"]
    if m.y_mode == "hadamard":
        # W_out was folded offline to H·W_out with 1/n in its scale
        s_yh = asc[f"l{i}.gated_h.s"]
        if use_pallas:
            y8 = hadamard_quant_pallas(gated, s_yh, m.a_bits)
        else:
            y8 = qc.quantize_sym(hu.fwht_jnp(gated), s_yh, m.a_bits)
        out = _mm(y8, w_out, s_yh, s_wo, use_pallas)
    elif m.y_mode == "fp":
        out = gated @ (w_out.astype(jnp.float32) * s_wo)
    else:
        if m.smooth_alpha is not None:
            gated = gated * asc[f"l{i}.smooth_y_inv"]
        if m.act_mode == "dynamic":
            gated, _ = qc.dynamic_fake_quant(gated, m.a_bits)
            out = gated @ (w_out.astype(jnp.float32) * s_wo)
        else:
            s_y = asc[f"l{i}.gated.s"]
            y8 = qc.quantize_sym(gated, s_y, m.a_bits)
            out = _mm(y8, w_out, s_y, s_wo, use_pallas)
    return out, new_conv, hT


def _conv_live_q(x_deq, conv_st, w_conv, s_wc, bias, s_x, a_bits, gain=None):
    """Int8-semantics conv with a live (non-zero) window: compute in f32
    on exactly-representable dequantized values, requantize with s_x.
    Bit-equivalent to the fused int8 kernel for fresh state."""
    W = w_conv.shape[0]
    T = x_deq.shape[1]
    w_deq = w_conv.astype(jnp.float32) * s_wc
    full = jnp.concatenate([conv_st, x_deq], axis=1)
    conv = sum(full[:, j : j + T, :] * w_deq[j][None, None, :] for j in range(W))
    act = ref.silu(conv + bias[None, None, :])
    if gain is not None:
        act = act * gain[None, None, :]
    return qc.quantize_sym(act, s_x, a_bits)


def forward_q(cfg: TierConfig, qa: QuantArtifacts, weights, tokens, conv_state, ssm_state,
              use_pallas: bool = True, fresh_state: bool = False, gains=None):
    """Quantized forward. Residual spine in f32; fused norm+requant
    between blocks; QuaRot additionally rotates the in_proj input."""
    m = qa.method
    resid = weights["embedding.weight"][tokens]
    new_conv, new_ssm = [], []
    out = jnp.zeros_like(resid)
    d = cfg.d_model
    for i in range(cfg.n_layer):
        p = f"layers.{i}."
        s_in = qa.ascales[p + "in_proj.weight.in_s"]
        nw = weights[p + "norm.weight"]
        if m.quarot:
            # explicit rotate-then-quantize on the block input; H folded
            # into in_proj offline (W' = H·W_in, 1/d in its scale)
            resid = resid + out
            x_f = ref.rmsnorm(resid, nw, cfg.eps)
            x8 = qc.quantize_sym(hu.fwht_jnp(x_f), s_in, m.a_bits)
        elif use_pallas:
            x8, resid = rmsnorm_resid_q_pallas(out, resid, nw, s_in, cfg.eps, m.a_bits)
        else:
            x8, resid = ref.rmsnorm_resid_q(out, resid, nw, s_in, cfg.eps, m.a_bits)
        out, c, s = _block_q(cfg, qa, weights, i, x8, conv_state[i], ssm_state[i],
                             use_pallas, fresh_state, gains)
        new_conv.append(c)
        new_ssm.append(s)
    resid = resid + out
    final = ref.rmsnorm(resid, weights["norm_f.weight"], cfg.eps)
    s_h = qa.ascales["head.in_s"]
    h8 = qc.quantize_sym(final, s_h, m.a_bits)
    logits = _mm(h8, weights["lm_head.weight"], s_h, qa.wscales["lm_head.weight.s"], use_pallas)
    return logits, jnp.stack(new_conv), jnp.stack(new_ssm)


# ---------------------------------------------------------------------------
# Weight-only (W2A16 Quip#-like) forward: fp activations, weights
# dequantized from their 2-bit incoherent (rotated) form.
# ---------------------------------------------------------------------------

def forward_weight_only(cfg: TierConfig, qa: QuantArtifacts, weights, tokens,
                        conv_state, ssm_state, gains=None):
    params = {}
    for name in param_names(cfg):
        if name + ".q" in weights:
            w_q = weights[name + ".q"].astype(jnp.float32)
            s = weights[name + ".q.s"]          # per-channel scale row
            params[name] = w_q * s
        else:
            params[name] = weights[name]
    return forward_fp(cfg, params, tokens, conv_state, ssm_state, gains=gains)
