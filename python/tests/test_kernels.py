"""L1 Pallas kernels vs their pure-jnp oracles — the core correctness
signal for the compute layer. Hypothesis sweeps shapes/dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels.causal_conv import causal_conv_silu_pallas, causal_conv_silu_q_pallas
from compile.kernels.hadamard import hadamard_quant_pallas
from compile.kernels.matmul_i8 import matmul_i8_pallas
from compile.kernels.rmsnorm import rmsnorm_resid_q_pallas
from compile.kernels.selective_scan import selective_scan_pallas, selective_scan_q_pallas

RNG = np.random.default_rng(0)


def _scan_inputs(b, t, di, n, seed=0):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(b, t, di)), jnp.float32)
    dt = jnp.asarray(np.abs(r.normal(size=(b, t, di))) * 0.1 + 0.01, jnp.float32)
    a = -jnp.asarray(np.abs(r.normal(size=(di, n))) + 0.5, jnp.float32)
    bb = jnp.asarray(r.normal(size=(b, t, n)), jnp.float32)
    c = jnp.asarray(r.normal(size=(b, t, n)), jnp.float32)
    d = jnp.asarray(r.normal(size=(di,)), jnp.float32)
    return x, dt, a, bb, c, d


def _q(x, s):
    return jnp.asarray(np.clip(np.round(np.asarray(x) / s), -128, 127).astype(np.int8))


class TestSelectiveScan:
    @given(
        b=st.sampled_from([1, 2]),
        t=st.sampled_from([1, 4, 17]),
        di=st.sampled_from([8, 32, 96]),
        n=st.sampled_from([4, 16]),
        seed=st.integers(0, 50),
    )
    @settings(max_examples=12, deadline=None)
    def test_fp_matches_ref(self, b, t, di, n, seed):
        x, dt, a, bb, c, d = _scan_inputs(b, t, di, n, seed)
        y0, h0 = ref.selective_scan(x, dt, a, bb, c, d)
        y1, h1 = selective_scan_pallas(x, dt, a, bb, c, d)
        np.testing.assert_allclose(y0, y1, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(h0, h1, rtol=1e-4, atol=1e-5)

    def test_quantized_matches_ref(self):
        x, dt, a, bb, c, d = _scan_inputs(2, 16, 64, 16, 7)
        sx, sa, sb, sc, sd = 0.05, 0.02, 0.03, 0.03, 0.02
        args = (_q(x, sx), sx, dt, _q(a, sa), sa, _q(bb, sb), sb, _q(c, sc), sc, _q(d, sd), sd)
        y0, h0 = ref.selective_scan_q(*args)
        y1, h1 = selective_scan_q_pallas(*args)
        np.testing.assert_allclose(y0, y1, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(h0, h1, rtol=1e-4, atol=1e-5)

    def test_initial_state_continuation(self):
        """scan(T) then scan(T, h0=hT) == scan(2T) — the property the
        serving prefill→decode chain relies on."""
        x, dt, a, bb, c, d = _scan_inputs(1, 8, 16, 4, 3)
        y_full, h_full = ref.selective_scan(x, dt, a, bb, c, d)
        y1, h1 = selective_scan_pallas(x[:, :4], dt[:, :4], a, bb[:, :4], c[:, :4], d)
        y2, h2 = selective_scan_pallas(x[:, 4:], dt[:, 4:], a, bb[:, 4:], c[:, 4:], d, h0=h1)
        np.testing.assert_allclose(np.concatenate([y1, y2], 1), y_full, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(h2, h_full, rtol=1e-4, atol=1e-5)

    def test_odd_channel_count_falls_back_to_smaller_blocks(self):
        x, dt, a, bb, c, d = _scan_inputs(1, 4, 24, 4, 9)  # 24 % 32 != 0
        y0, _ = ref.selective_scan(x, dt, a, bb, c, d)
        y1, _ = selective_scan_pallas(x, dt, a, bb, c, d)
        np.testing.assert_allclose(y0, y1, rtol=1e-4, atol=1e-5)


class TestHadamardQuant:
    @pytest.mark.parametrize("n", [64, 96, 128, 160, 192, 256, 320])
    def test_matches_ref(self, n):
        y = jnp.asarray(RNG.normal(size=(2, 8, n)), jnp.float32)
        a = ref.hadamard_quant(y, 0.1)
        b = hadamard_quant_pallas(y, 0.1)
        assert int(np.abs(a.astype(np.int32) - b.astype(np.int32)).max()) == 0

    def test_4bit(self):
        y = jnp.asarray(RNG.normal(size=(1, 8, 64)), jnp.float32)
        b = hadamard_quant_pallas(y, 0.5, nbits=4)
        assert int(np.asarray(b).max()) <= 7 and int(np.asarray(b).min()) >= -8

    @given(rows=st.sampled_from([1, 3, 8, 16]), seed=st.integers(0, 20))
    @settings(max_examples=10, deadline=None)
    def test_row_counts(self, rows, seed):
        r = np.random.default_rng(seed)
        y = jnp.asarray(r.normal(size=(rows, 96)), jnp.float32)
        a = ref.hadamard_quant(y, 0.2)
        b = hadamard_quant_pallas(y, 0.2)
        assert int(np.abs(a.astype(np.int32) - b.astype(np.int32)).max()) == 0


class TestCausalConv:
    def test_fp_matches_ref(self):
        x = jnp.asarray(RNG.normal(size=(2, 12, 64)), jnp.float32)
        w = jnp.asarray(RNG.normal(size=(4, 64)), jnp.float32)
        b = jnp.asarray(RNG.normal(size=(64,)), jnp.float32)
        np.testing.assert_allclose(
            ref.causal_conv_silu(x, w, b), causal_conv_silu_pallas(x, w, b),
            rtol=1e-5, atol=1e-6)

    @given(t=st.sampled_from([1, 5, 16]), di=st.sampled_from([8, 32, 64]), seed=st.integers(0, 30))
    @settings(max_examples=10, deadline=None)
    def test_quantized_matches_ref(self, t, di, seed):
        r = np.random.default_rng(seed)
        x = r.normal(size=(1, t, di)).astype(np.float32)
        w = r.normal(size=(4, di)).astype(np.float32)
        bias = jnp.asarray(r.normal(size=(di,)), jnp.float32)
        xq, wq = _q(x, 0.05), _q(w, 0.04)
        a = ref.causal_conv_silu_q(xq, 0.05, wq, 0.04, bias, 0.02)
        b = causal_conv_silu_q_pallas(xq, 0.05, wq, 0.04, bias, 0.02)
        assert int(np.abs(np.asarray(a, np.int32) - np.asarray(b, np.int32)).max()) == 0

    def test_gain_applied(self):
        """per-channel post-SiLU gain (outlier injection) must match."""
        r = np.random.default_rng(1)
        di = 16
        x = r.normal(size=(1, 8, di)).astype(np.float32)
        w = r.normal(size=(4, di)).astype(np.float32)
        bias = jnp.zeros((di,), jnp.float32)
        gain = jnp.asarray(np.where(np.arange(di) == 3, 50.0, 1.0), jnp.float32)
        xq, wq = _q(x, 0.05), _q(w, 0.04)
        a = ref.causal_conv_silu_q(xq, 0.05, wq, 0.04, bias, 0.1, gain=gain)
        b = causal_conv_silu_q_pallas(xq, 0.05, wq, 0.04, bias, 0.1, gain=gain)
        assert int(np.abs(np.asarray(a, np.int32) - np.asarray(b, np.int32)).max()) == 0
        assert int(np.abs(np.asarray(a)[..., 3]).max()) > int(np.abs(np.asarray(a)[..., 4]).max())

    def test_causality(self):
        """future tokens must not affect earlier outputs."""
        x = np.zeros((1, 8, 8), np.float32)
        x2 = x.copy()
        x2[0, 7, :] = 100.0
        w = jnp.asarray(RNG.normal(size=(4, 8)), jnp.float32)
        b = jnp.zeros((8,), jnp.float32)
        y1 = np.asarray(causal_conv_silu_pallas(jnp.asarray(x), w, b))
        y2 = np.asarray(causal_conv_silu_pallas(jnp.asarray(x2), w, b))
        np.testing.assert_array_equal(y1[:, :7], y2[:, :7])
        assert np.abs(y2[:, 7] - y1[:, 7]).max() > 0


class TestRmsNorm:
    @given(rows=st.sampled_from([1, 8, 24]), d=st.sampled_from([16, 64, 160]), seed=st.integers(0, 30))
    @settings(max_examples=10, deadline=None)
    def test_matches_ref(self, rows, d, seed):
        r = np.random.default_rng(seed)
        xo = jnp.asarray(r.normal(size=(rows, d)), jnp.float32)
        xr = jnp.asarray(r.normal(size=(rows, d)), jnp.float32)
        w = jnp.asarray(r.normal(size=(d,)), jnp.float32)
        a1, a2 = ref.rmsnorm_resid_q(xo, xr, w, 0.03)
        b1, b2 = rmsnorm_resid_q_pallas(xo, xr, w, 0.03)
        assert int(np.abs(np.asarray(a1, np.int32) - np.asarray(b1, np.int32)).max()) == 0
        np.testing.assert_allclose(a2, b2, rtol=1e-6)

    def test_residual_passthrough_exact(self):
        xo = jnp.asarray(RNG.normal(size=(4, 32)), jnp.float32)
        xr = jnp.asarray(RNG.normal(size=(4, 32)), jnp.float32)
        w = jnp.ones((32,), jnp.float32)
        _, res = rmsnorm_resid_q_pallas(xo, xr, w, 0.1)
        np.testing.assert_array_equal(np.asarray(res), np.asarray(xo + xr))


class TestMatmulI8:
    @given(
        m=st.sampled_from([1, 7, 64]),
        k=st.sampled_from([16, 48]),
        n=st.sampled_from([8, 40, 64, 128]),
        seed=st.integers(0, 30),
    )
    @settings(max_examples=12, deadline=None)
    def test_matches_ref(self, m, k, n, seed):
        r = np.random.default_rng(seed)
        x = jnp.asarray(r.integers(-127, 128, size=(m, k)), jnp.int8)
        w = jnp.asarray(r.integers(-127, 128, size=(k, n)), jnp.int8)
        a = ref.matmul_i8(x, w, 0.1, 0.2)
        b = matmul_i8_pallas(x, w, 0.1, 0.2)
        np.testing.assert_allclose(a, b, rtol=1e-6)

    def test_bias(self):
        r = np.random.default_rng(9)
        x = jnp.asarray(r.integers(-127, 128, size=(3, 8)), jnp.int8)
        w = jnp.asarray(r.integers(-127, 128, size=(8, 16)), jnp.int8)
        bias = jnp.asarray(r.normal(size=(16,)), jnp.float32)
        a = ref.matmul_i8(x, w, 0.1, 0.2, bias)
        b = matmul_i8_pallas(x, w, 0.1, 0.2, bias)
        np.testing.assert_allclose(a, b, rtol=1e-6)

    def test_i32_accumulation_no_overflow(self):
        """worst-case int8 products must accumulate exactly in i32."""
        k = 512
        x = jnp.full((1, k), 127, jnp.int8)
        w = jnp.full((k, 8), 127, jnp.int8)
        out = matmul_i8_pallas(x, w, 1.0, 1.0)
        assert float(out[0, 0]) == 127.0 * 127.0 * k

    def test_batched_leading_dims(self):
        r = np.random.default_rng(11)
        x = jnp.asarray(r.integers(-10, 10, size=(2, 5, 16)), jnp.int8)
        w = jnp.asarray(r.integers(-10, 10, size=(16, 8)), jnp.int8)
        a = ref.matmul_i8(x, w, 0.5, 0.5)
        b = matmul_i8_pallas(x, w, 0.5, 0.5)
        assert b.shape == (2, 5, 8)
        np.testing.assert_allclose(a, b, rtol=1e-6)
