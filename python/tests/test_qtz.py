"""qtz container format round-trips."""

import numpy as np
import pytest

from compile import qtz


def test_roundtrip_all_dtypes(tmp_path):
    p = str(tmp_path / "t.qtz")
    tensors = {
        "f32": np.arange(12, dtype=np.float32).reshape(3, 4),
        "i8": np.array([-128, 0, 127], dtype=np.int8),
        "i32": np.array([[2**30, -5]], dtype=np.int32),
        "u16": np.array([0, 65535], dtype=np.uint16),
        "i64": np.array([2**40], dtype=np.int64),
        "u8": np.frombuffer(b"hello", dtype=np.uint8),
    }
    qtz.save(p, tensors)
    back = qtz.load(p)
    assert list(back.keys()) == list(tensors.keys())
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])
        assert back[k].dtype.itemsize == tensors[k].dtype.itemsize


def test_scalar_and_empty(tmp_path):
    p = str(tmp_path / "s.qtz")
    qtz.save(p, {"scalar": np.float32(3.5), "empty": np.zeros((0, 4), np.float32)})
    back = qtz.load(p)
    assert back["scalar"].shape == ()
    assert float(back["scalar"]) == 3.5
    assert back["empty"].shape == (0, 4)


def test_bad_magic(tmp_path):
    p = tmp_path / "bad.qtz"
    p.write_bytes(b"NOPE1234")
    with pytest.raises(ValueError):
        qtz.load(str(p))


def test_unsupported_dtype():
    with pytest.raises(ValueError):
        qtz.dtype_code(np.dtype(np.float64))


def test_preserves_order(tmp_path):
    p = str(tmp_path / "o.qtz")
    names = [f"t{i}" for i in range(20)]
    qtz.save(p, {n: np.array([i], np.int32) for i, n in enumerate(names)})
    assert list(qtz.load(p).keys()) == names
