"""Quantization primitives (paper Eq. 2 + Table 9 variants)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.quant import core as qc


class TestSymmetric:
    def test_roundtrip_error_bounded_by_half_step(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=1000), jnp.float32)
        s = float(qc.scale_sym(float(jnp.abs(x).max()), 8))
        xq = qc.fake_quant_sym(x, s, 8)
        assert float(jnp.abs(x - xq).max()) <= s / 2 + 1e-7

    def test_range_clamp(self):
        x = jnp.asarray([1e6, -1e6], jnp.float32)
        q = qc.quantize_sym(x, 1.0, 8)
        assert int(q[0]) == 127 and int(q[1]) == -128

    @given(st.integers(2, 8), st.integers(0, 100))
    @settings(max_examples=30, deadline=None)
    def test_codes_in_range_any_bitwidth(self, nbits, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=64) * 10, jnp.float32)
        s = float(qc.scale_sym(float(jnp.abs(x).max()), nbits))
        q = qc.quantize_sym(x, s, nbits, dtype=jnp.int32)
        assert int(q.max()) <= qc.qmax(nbits)
        assert int(q.min()) >= qc.qmin(nbits)

    def test_zero_scale_guard(self):
        s = qc.scale_sym(0.0, 8)
        assert s > 0


class TestPercentile:
    def test_percentile_ignores_outliers(self):
        x = np.full(100_000, 0.5, np.float32)
        x[:5] = 50.0
        assert qc.percentile_amax(x, 99.9) < 1.0
        assert qc.percentile_amax(x, 100.0) == 50.0

    def test_monotone_in_p(self):
        rng = np.random.default_rng(1)
        x = rng.normal(size=10000)
        vals = [qc.percentile_amax(x, p) for p in (99.0, 99.9, 99.99, 100.0)]
        assert vals == sorted(vals)


class TestAsymmetric:
    def test_recovers_skewed_range(self):
        x = jnp.asarray(np.linspace(-0.1, 3.0, 128), jnp.float32)
        s, z = qc.asym_params(-0.1, 3.0, 8)
        xr = qc.fake_quant_asym(x, s, z, 8)
        assert float(jnp.abs(x - xr).max()) < s + 1e-6

    def test_asym_beats_sym_on_skewed_data(self):
        rng = np.random.default_rng(2)
        x = np.abs(rng.normal(size=4096)).astype(np.float32) + 1.0  # all ≥ 1
        xj = jnp.asarray(x)
        s_sym = float(qc.scale_sym(float(np.abs(x).max()), 8))
        err_sym = float(jnp.mean((xj - qc.fake_quant_sym(xj, s_sym, 8)) ** 2))
        s, z = qc.asym_params(float(x.min()), float(x.max()), 8)
        err_asym = float(jnp.mean((xj - qc.fake_quant_asym(xj, s, z, 8)) ** 2))
        assert err_asym < err_sym


class TestLog2:
    def test_small_values_survive(self):
        """log2 keeps small magnitudes that a skewed uniform grid kills."""
        x = jnp.asarray([0.001, 0.01, 0.1, 1.0, 10.0], jnp.float32)
        amax = 10.0
        uni = qc.fake_quant_sym(x, float(qc.scale_sym(amax, 8)), 8)
        log = qc.fake_quant_log2(x, amax, 8)
        # relative error of the small entries
        rel_uni = float(jnp.abs(uni[0] - x[0]) / x[0])
        rel_log = float(jnp.abs(log[0] - x[0]) / x[0])
        assert rel_log < rel_uni

    def test_sign_preserved(self):
        x = jnp.asarray([-0.5, 0.5], jnp.float32)
        y = qc.fake_quant_log2(x, 1.0, 8)
        assert float(y[0]) < 0 < float(y[1])


class TestDynamic:
    def test_dynamic_scale_tracks_tensor(self):
        x = jnp.asarray([0.1, -0.2, 0.05], jnp.float32)
        _, s = qc.dynamic_fake_quant(x, 8)
        assert abs(float(s) - 0.2 / 127) < 1e-9


class TestWeightQuant:
    def test_per_tensor(self):
        rng = np.random.default_rng(3)
        w = rng.normal(size=(32, 16)).astype(np.float32)
        q, s = qc.quantize_weight_np(w, 8)
        assert q.dtype == np.int8
        np.testing.assert_allclose(q.astype(np.float32) * s, w, atol=s)

    def test_per_channel_tighter_than_per_tensor(self):
        rng = np.random.default_rng(4)
        w = rng.normal(size=(16, 8)).astype(np.float32)
        w[0] *= 100.0  # one huge row
        q_t, s_t = qc.quantize_weight_np(w, 8)
        q_c, s_c = qc.quantize_weight_perchannel_np(w, axis=0, nbits=8)
        err_t = np.abs(q_t.astype(np.float32) * s_t - w)[1:].max()
        err_c = np.abs(q_c.astype(np.float32) * s_c - w)[1:].max()
        assert err_c < err_t

    def test_low_bit_codes(self):
        w = np.linspace(-1, 1, 64).astype(np.float32).reshape(8, 8)
        q, _ = qc.quantize_weight_np(w, 2)
        assert set(np.unique(q)) <= {-2, -1, 0, 1}


class TestMixed:
    def test_llm_int8_outlier_split(self):
        from compile.quant.mixed import matmul_mixed, outlier_columns, split_weight

        rng = np.random.default_rng(5)
        k, n = 32, 16
        w = rng.normal(size=(k, n)).astype(np.float32)
        x = rng.normal(size=(4, k)).astype(np.float32)
        chan = np.abs(x).max(axis=0)
        chan[3] = 100.0
        x[:, 3] = rng.normal(size=4) * 100
        o = outlier_columns(chan, threshold=6.0)
        assert 3 in o
        parts = split_weight(w, o)
        s_rest = float(np.abs(np.delete(x, o, axis=1)).max() / 127)
        y = np.asarray(matmul_mixed(jnp.asarray(x), parts, s_rest))
        np.testing.assert_allclose(y, x @ w, rtol=0.05, atol=0.2)
