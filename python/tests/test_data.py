"""Synthetic corpus + task-suite substrate."""

import numpy as np
import pytest

from compile import data as dm


@pytest.fixture(scope="module")
def lms():
    return dm.make_corpora(seed=11)


class TestVocab:
    def test_size_and_uniqueness(self):
        v = dm.Vocab()
        assert len(v.words) == dm.N_WORDS
        assert len(set(v.words)) == dm.N_WORDS

    def test_decode(self):
        v = dm.Vocab()
        ids = [dm.BOS, 4, 5, dm.SEP, 6, dm.EOS, 7]
        s = v.decode(ids)
        assert v.words[0] in s and "<sep>" in s
        assert v.words[3] not in s  # after EOS

    def test_deterministic(self):
        assert dm.Vocab(seed=7).words == dm.Vocab(seed=7).words
        assert dm.Vocab(seed=7).words != dm.Vocab(seed=8).words


class TestMarkov:
    def test_streams_deterministic(self, lms):
        pile, _ = lms
        a = dm.token_stream(pile, 500, seed=3)
        b = dm.token_stream(pile, 500, seed=3)
        np.testing.assert_array_equal(a, b)

    def test_styles_differ(self, lms):
        pile, wiki = lms
        a = dm.token_stream(pile, 2000, seed=3)
        b = dm.token_stream(wiki, 2000, seed=3)
        # unigram histograms must differ measurably (they are the two
        # eval distributions in Table 2)
        ha = np.bincount(a, minlength=256) / len(a)
        hb = np.bincount(b, minlength=256) / len(b)
        assert np.abs(ha - hb).sum() > 0.1

    def test_tokens_in_range(self, lms):
        pile, _ = lms
        s = dm.token_stream(pile, 1000, seed=4)
        assert s.max() < dm.VOCAB_SIZE
        assert (s >= dm.SEP).all()  # no PAD/BOS/EOS inside a stream

    def test_distribution_learnable(self, lms):
        """the chain must be peaked (low-entropy next-token dist), else
        training could never beat unigram and the eval would be noise."""
        pile, _ = lms
        p = pile.next_dist(3, 7)
        assert p.max() > 5.0 / dm.N_WORDS  # much more peaked than uniform

    def test_batches_shapes(self, lms):
        pile, _ = lms
        s = dm.token_stream(pile, 3000, seed=5)
        x, y = next(dm.batches(s, 4, 32, seed=0))
        assert x.shape == (4, 32) and y.shape == (4, 32)
        np.testing.assert_array_equal(x[:, 1:], y[:, :-1])


class TestTasks:
    @pytest.fixture(scope="class")
    def suite(self, lms):
        return dm.build_task_suite(lms[0], n_ex=12)

    def test_all_six_tasks(self, suite):
        assert list(suite.keys()) == [
            "lambada_synth", "hellaswag_synth", "piqa_synth",
            "arc_easy_synth", "arc_chal_synth", "winogrande_synth",
        ]
        for name, t in suite.items():
            assert len(t["examples"]) == 12, name

    def test_choice_golds_valid(self, suite):
        for name, t in suite.items():
            if t["kind"].startswith("choice"):
                for ex in t["examples"]:
                    assert 0 <= ex["gold"] < len(ex["choices"])
                    lens = {len(c) for c in ex["choices"]}
                    assert len(lens) == 1, "choices must be same length for fairness"

    def test_lambada_target_is_modal_continuation(self, suite, lms):
        """the target must be the generator's argmax continuation of the
        prompt's final word bigram (the solvable-by-training design)."""
        import numpy as np

        pile = lms[0]
        for ex in suite["lambada_synth"]["examples"]:
            w1 = ex["prompt"][-2] - dm.N_SPECIAL
            w2 = ex["prompt"][-1] - dm.N_SPECIAL
            assert w1 >= 0 and w2 >= 0, "prompt must end with two words"
            want = int(np.argmax(pile.next_dist(w1, w2))) + dm.N_SPECIAL
            assert ex["target"][0] == want

    def test_gold_not_trivially_positional(self, suite):
        """gold indices must be shuffled, not always 0."""
        golds = [ex["gold"] for ex in suite["piqa_synth"]["examples"]]
        assert len(set(golds)) > 1

    def test_deterministic(self, lms):
        a = dm.build_task_suite(lms[0], n_ex=5)
        b = dm.build_task_suite(lms[0], n_ex=5)
        for k in a:
            assert a[k]["examples"][0]["prompt"] == b[k]["examples"][0]["prompt"]
