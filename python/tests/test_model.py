"""L2 model graphs: fp reference vs quantized deployment variants,
prefill/decode consistency, fold exactness (the compute-invariance
claims of paper §4.2), and outlier-injection invariance."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as mm
from compile import outliers as om
from compile.quant import calibrate as cal
from compile.quant import config as qconf
from compile.quant import hadamard_util as hu

TINY = mm.TierConfig("tiny", "Tiny", d_model=32, n_layer=2)


@pytest.fixture(scope="module")
def setup():
    """Tiny trained-ish setup: random weights + synthetic calibration."""
    from compile import data as dm

    params = mm.init_params(TINY, seed=0)
    lm, _ = dm.make_corpora()
    stream = dm.token_stream(lm, 6000, seed=5)
    gains = om.OutlierSpec.for_tier(TINY, 1)
    stats = cal.calibrate(TINY, params, stream, n_samples=8, seqlen=32, batch=4, gains=gains)
    return params, stream, gains, stats


def _toks(stream, b, t, off=0):
    return jnp.asarray(
        np.stack([stream[off + i * t : off + (i + 1) * t] for i in range(b)]).astype(np.int32)
    )


class TestForwardFp:
    def test_shapes(self, setup):
        params, stream, gains, _ = setup
        p = {k: jnp.asarray(v) for k, v in params.items()}
        toks = _toks(stream, 2, 16)
        logits, conv, ssm = mm.forward_fp(TINY, p, toks)
        assert logits.shape == (2, 16, TINY.vocab)
        assert conv.shape == (2, 2, 3, 64)
        assert ssm.shape == (2, 2, 64, 16)

    def test_prefill_decode_consistency(self, setup):
        """prefill(T) then stepping == prefill(T+k): the serving chain."""
        params, stream, gains, _ = setup
        p = {k: jnp.asarray(v) for k, v in params.items()}
        toks = _toks(stream, 1, 12)
        logits_full, _, _ = mm.forward_fp(TINY, p, toks)
        l8, conv, ssm = mm.forward_fp(TINY, p, toks[:, :8])
        outs = []
        for i in range(8, 12):
            li, conv, ssm = mm.forward_fp(TINY, p, toks[:, i : i + 1], conv, ssm)
            outs.append(li[:, 0])
        np.testing.assert_allclose(
            np.stack(outs, 1), np.asarray(logits_full[:, 8:]), rtol=2e-3, atol=2e-4)

    def test_gain_injection_function_preserving_at_init(self, setup):
        """with compensated consumers, gains don't change the function
        class — here we check the *mechanism*: gains scale the tapped
        tensors exactly."""
        params, stream, gains, _ = setup
        p = {k: jnp.asarray(v) for k, v in params.items()}
        toks = _toks(stream, 1, 8)
        g = (jnp.asarray(gains.g_x), jnp.asarray(gains.g_y))
        _, _, _, taps = mm.forward_fp(TINY, p, toks, collect=True, gains=g)
        _, _, _, taps0 = mm.forward_fp(TINY, p, toks, collect=True)
        gx = np.asarray(gains.g_x[0])
        got = np.asarray(taps["l0.x_ssm"])
        want = np.asarray(taps0["l0.x_ssm"]) * gx[None, None, :]
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


class TestQuantizedForward:
    @pytest.mark.parametrize("mname", ["quamba", "w8a8_static", "smoothquant", "quamba_inper",
                                       "quamba_outhad", "t9_asym", "t9_log2", "io_fp_fp"])
    def test_close_to_fp(self, setup, mname):
        params, stream, gains, stats = setup
        method = qconf.METHODS[mname]
        qa = cal.build_artifacts(TINY, params, method, stats)
        w = {k: jnp.asarray(v) for k, v in qa.weights.items()}
        p = {k: jnp.asarray(v) for k, v in params.items()}
        toks = _toks(stream, 1, 16)
        g = (jnp.asarray(gains.g_x), jnp.asarray(gains.g_y))
        conv, ssm = mm.zero_states(TINY, 1)
        logits_fp, _, _ = mm.forward_fp(TINY, p, toks, gains=g)
        logits_q, _, _ = mm.forward_q(TINY, qa, w, toks, conv, ssm,
                                      use_pallas=False, fresh_state=True, gains=g)
        # top-1 agreement is the functional bar for W8A8
        agree = (np.argmax(np.asarray(logits_q), -1) == np.argmax(np.asarray(logits_fp), -1)).mean()
        assert agree > 0.5, f"{mname}: top-1 agreement {agree}"

    def test_pallas_equals_jnp_path(self, setup):
        """the deployment graph (pallas kernels) must match the pure-jnp
        quantized path bit-for-bit-ish."""
        params, stream, gains, stats = setup
        qa = cal.build_artifacts(TINY, params, qconf.METHODS["quamba"], stats)
        w = {k: jnp.asarray(v) for k, v in qa.weights.items()}
        toks = _toks(stream, 1, 16)
        g = (jnp.asarray(gains.g_x), jnp.asarray(gains.g_y))
        conv, ssm = mm.zero_states(TINY, 1)
        l1, c1, s1 = mm.forward_q(TINY, qa, w, toks, conv, ssm, use_pallas=False,
                                  fresh_state=True, gains=g)
        l2, c2, s2 = mm.forward_q(TINY, qa, w, toks, conv, ssm, use_pallas=True,
                                  fresh_state=True, gains=g)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-3, atol=1e-4)

    def test_quantized_prefill_decode_consistency(self, setup):
        params, stream, gains, stats = setup
        qa = cal.build_artifacts(TINY, params, qconf.METHODS["quamba"], stats)
        w = {k: jnp.asarray(v) for k, v in qa.weights.items()}
        toks = _toks(stream, 1, 12)
        g = (jnp.asarray(gains.g_x), jnp.asarray(gains.g_y))
        conv, ssm = mm.zero_states(TINY, 1)
        lf, _, _ = mm.forward_q(TINY, qa, w, toks, conv, ssm, use_pallas=False,
                                fresh_state=True, gains=g)
        _, c, s = mm.forward_q(TINY, qa, w, toks[:, :8], conv, ssm, use_pallas=False,
                               fresh_state=True, gains=g)
        outs = []
        for i in range(8, 12):
            li, c, s = mm.forward_q(TINY, qa, w, toks[:, i : i + 1], c, s,
                                    use_pallas=False, fresh_state=False, gains=g)
            outs.append(np.asarray(li[:, 0]))
        np.testing.assert_allclose(np.stack(outs, 1), np.asarray(lf[:, 8:]),
                                   rtol=5e-3, atol=5e-3)

    def test_hadamard_fold_compute_invariance(self, setup):
        """paper §4.2: W_outᵀy == (1/n)(H W_out)ᵀ(H y) — the fold must be
        exact in fp before quantization enters."""
        rng = np.random.default_rng(0)
        n = TINY.d_inner
        w = rng.normal(size=(n, TINY.d_model)).astype(np.float32)
        y = rng.normal(size=(5, n)).astype(np.float32)
        h = hu.hadamard_np(n)
        direct = y @ w
        folded = (np.asarray(hu.fwht(y)) @ (h @ w)) / n
        np.testing.assert_allclose(direct, folded, rtol=1e-3, atol=1e-4)

    def test_smoothquant_fold_exactness(self, setup):
        """norm-weight folding: rmsnorm(x)·(w/s) @ (diag(s)W) == rmsnorm(x)·w @ W."""
        rng = np.random.default_rng(1)
        d = 16
        x = jnp.asarray(rng.normal(size=(4, d)), jnp.float32)
        nw = jnp.asarray(rng.normal(size=(d,)) + 2.0, jnp.float32)
        w = rng.normal(size=(d, 8)).astype(np.float32)
        s = np.abs(rng.normal(size=d)).astype(np.float32) + 0.5
        from compile.kernels import ref

        direct = ref.rmsnorm(x, nw) @ w
        folded = ref.rmsnorm(x, nw / s) @ (w * s[:, None])
        np.testing.assert_allclose(np.asarray(direct), np.asarray(folded), rtol=1e-4, atol=1e-5)

    def test_quarot_forward_runs(self, setup):
        params, stream, gains, stats = setup
        qa = cal.build_artifacts(TINY, params, qconf.METHODS["quarot"], stats)
        w = {k: jnp.asarray(v) for k, v in qa.weights.items()}
        toks = _toks(stream, 1, 8)
        conv, ssm = mm.zero_states(TINY, 1)
        g = (jnp.asarray(gains.g_x), jnp.asarray(gains.g_y))
        logits, _, _ = mm.forward_q(TINY, qa, w, toks, conv, ssm, use_pallas=False,
                                    fresh_state=True, gains=g)
        assert np.isfinite(np.asarray(logits)).all()

    def test_weight_only_w2a16_degrades_but_runs(self, setup):
        params, stream, gains, stats = setup
        qa = cal.build_artifacts(TINY, params, qconf.METHODS["w2a16_quip"], stats)
        w = {k: jnp.asarray(v) for k, v in qa.weights.items()}
        toks = _toks(stream, 1, 8)
        conv, ssm = mm.zero_states(TINY, 1)
        g = (jnp.asarray(gains.g_x), jnp.asarray(gains.g_y))
        logits, _, _ = mm.forward_weight_only(TINY, qa, w, toks, conv, ssm, gains=g)
        assert np.isfinite(np.asarray(logits)).all()


class TestOutlierInjection:
    def test_conv_in_injection_exactly_invariant(self, setup):
        params, stream, _, _ = setup
        p1 = {k: jnp.asarray(v) for k, v in params.items()}
        inj = om.inject_conv_in(TINY, params, alpha=8.0, k=2)
        p2 = {k: jnp.asarray(v) for k, v in inj.items()}
        toks = _toks(stream, 1, 12)
        l1, _, _ = mm.forward_fp(TINY, p1, toks)
        l2, _, _ = mm.forward_fp(TINY, p2, toks)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-3, atol=1e-3)
        # but the conv_in tap must now carry outliers
        _, _, _, t1 = mm.forward_fp(TINY, p1, toks, collect=True)
        _, _, _, t2 = mm.forward_fp(TINY, p2, toks, collect=True)
        assert np.abs(np.asarray(t2["l0.conv_in"])).max() > 3 * np.abs(np.asarray(t1["l0.conv_in"])).max()

    def test_gains_create_y_outliers(self, setup):
        params, stream, gains, _ = setup
        p = {k: jnp.asarray(v) for k, v in params.items()}
        toks = _toks(stream, 1, 16)
        g = (jnp.asarray(gains.g_x), jnp.asarray(gains.g_y))
        _, _, _, taps = mm.forward_fp(TINY, p, toks, collect=True, gains=g)
        gated = np.abs(np.asarray(taps["l1.gated"]))
        chan_max = gated.reshape(-1, gated.shape[-1]).max(0)
        # outlier channels dominate the median channel by ≥ 5×
        assert chan_max.max() > 5 * np.median(chan_max)

    def test_hadamard_suppresses_injected_outliers(self, setup):
        params, stream, gains, _ = setup
        p = {k: jnp.asarray(v) for k, v in params.items()}
        toks = _toks(stream, 1, 16)
        g = (jnp.asarray(gains.g_x), jnp.asarray(gains.g_y))
        _, _, _, taps = mm.forward_fp(TINY, p, toks, collect=True, gains=g)
        a_raw = np.abs(np.asarray(taps["l1.gated"])).max()
        a_rot = np.abs(np.asarray(taps["l1.gated_h"])).max()
        n = TINY.d_inner
        # rotation spreads the outlier: amax grows far less than the
        # energy-preserving worst case √n while the scale now covers a
        # near-uniform tensor
        assert a_rot < a_raw * np.sqrt(n) / 2


class TestCalibration:
    def test_scales_positive_and_complete(self, setup):
        params, _, _, stats = setup
        qa = cal.build_artifacts(TINY, params, qconf.METHODS["quamba"], stats)
        for k, v in qa.ascales.items():
            if isinstance(v, tuple):
                assert v[0] > 0
            elif isinstance(v, np.ndarray):
                assert (v > 0).all()
            else:
                assert v > 0, k
        for i in range(TINY.n_layer):
            assert f"l{i}.x_ssm.s" in qa.ascales
            assert f"l{i}.gated_h.s" in qa.ascales

    def test_percentile_scale_smaller_than_minmax(self, setup):
        params, _, _, stats = setup
        qa_p = cal.build_artifacts(TINY, params, qconf.METHODS["quamba"], stats)
        qa_m = cal.build_artifacts(TINY, params, qconf.METHODS["quamba_outhad"], stats)
        for i in range(TINY.n_layer):
            assert qa_p.ascales[f"l{i}.x_ssm.s"] <= qa_m.ascales[f"l{i}.x_ssm.s"] + 1e-12

    def test_int8_weights_dtype(self, setup):
        params, _, _, stats = setup
        qa = cal.build_artifacts(TINY, params, qconf.METHODS["quamba"], stats)
        assert qa.weights["layers.0.in_proj.weight"].dtype == np.int8
        assert qa.weights["layers.0.A_q"].dtype == np.int8
        assert qa.weights["layers.0.norm.weight"].dtype == np.float32

    def test_quantized_bundle_smaller_than_fp(self, setup):
        params, _, _, stats = setup
        qa = cal.build_artifacts(TINY, params, qconf.METHODS["quamba"], stats)
        q_bytes = sum(np.asarray(v).nbytes for v in qa.weights.values())
        f_bytes = sum(np.asarray(v).nbytes for v in params.values())
        assert q_bytes < 0.65 * f_bytes  # ≈ halved minus fp embedding
