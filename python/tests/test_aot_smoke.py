"""End-to-end AOT smoke: the --quick build must produce a loadable,
self-consistent artifact tree (graphs in HLO text, weights in qtz,
manifest indexing both). The rust side consumes the same tree in
rust/tests/integration.rs."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from compile import qtz

ART = "/tmp/quamba_pytest_artifacts"


@pytest.fixture(scope="module")
def quick_build():
    # reuse a previous build in the same session if present
    manifest = os.path.join(ART, "manifest.json")
    if not os.path.exists(manifest):
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", ART, "--quick"],
            check=True,
            cwd=os.path.join(os.path.dirname(__file__), ".."),
            timeout=900,
        )
    with open(manifest) as f:
        return json.load(f)


def test_manifest_structure(quick_build):
    m = quick_build
    assert m["vocab_size"] == 256
    assert m["quick"] is True
    assert len(m["graphs"]) >= 6
    for g in m["graphs"].values():
        assert g["kind"] in ("prefill", "decode")
        assert os.path.exists(os.path.join(ART, g["file"]))


def test_graphs_are_hlo_text(quick_build):
    g = next(iter(quick_build["graphs"].values()))
    text = open(os.path.join(ART, g["file"])).read()
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_weights_match_manifest_params(quick_build):
    for key, w in quick_build["weights"].items():
        f = qtz.load(os.path.join(ART, w["file"]))
        for p in w["params"]:
            assert p in f, f"{key}: missing {p}"


def test_quantized_weights_are_int8(quick_build):
    key = next(k for k in quick_build["weights"] if k.endswith("_quamba"))
    f = qtz.load(os.path.join(ART, quick_build["weights"][key]["file"]))
    assert f["layers.0.in_proj.weight"].dtype == np.int8
    # size reduction vs fp bundle (the Table 1 "Size" claim)
    fp_key = key.replace("_quamba", "_fp16")
    assert quick_build["weights"][key]["bytes"] < 0.65 * quick_build["weights"][fp_key]["bytes"]


def test_eval_data_present(quick_build):
    for k in ("calib", "pile_eval", "wiki_eval", "tasks", "vocab"):
        assert os.path.exists(os.path.join(ART, quick_build["data"][k]))
    tasks = json.load(open(os.path.join(ART, quick_build["data"]["tasks"])))
    assert len(tasks) == 6


def test_gains_shipped_for_reference_sim(quick_build):
    key = next(k for k in quick_build["weights"] if k.endswith("_fp16"))
    f = qtz.load(os.path.join(ART, quick_build["weights"][key]["file"]))
    assert "__gains.g_x" in f and "__gains.g_y" in f
