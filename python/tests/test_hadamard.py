"""Walsh-Hadamard transform + Paley constructions (paper §3.3)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.quant import hadamard_util as hu


@pytest.mark.parametrize("q", [11, 19])
def test_paley_orthogonal(q):
    h = hu.paley_hadamard(q)
    n = q + 1
    assert ((h @ h.T) == n * np.eye(n, dtype=np.int64)).all()
    assert set(np.unique(h)) <= {-1, 1}


@pytest.mark.parametrize("n", [1, 2, 4, 8, 12, 16, 20, 24, 64, 96, 128, 160, 192, 256, 320])
def test_hadamard_orthogonal(n):
    h = hu.hadamard(n)
    assert ((h @ h.T) == n * np.eye(n, dtype=np.int64)).all()


@pytest.mark.parametrize(
    "n,expect",
    [(128, (7, 1)), (192, (4, 12)), (256, (8, 1)), (320, (4, 20)), (96, (3, 12)), (64, (6, 1))],
)
def test_decompose(n, expect):
    assert hu.decompose(n) == expect


@pytest.mark.parametrize("n", [7, 9, 15, 28 * 3])
def test_decompose_rejects(n):
    with pytest.raises(ValueError):
        hu.decompose(n)


@pytest.mark.parametrize("n", [8, 64, 96, 128, 160, 192, 256, 320])
def test_fwht_matches_matrix(n):
    rng = np.random.default_rng(n)
    x = rng.normal(size=(5, n)).astype(np.float32)
    want = x @ hu.hadamard(n).astype(np.float64).T  # (H x) rowwise
    got = hu.fwht(x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n", [64, 96, 192, 320])
def test_fwht_jnp_matches_numpy(n):
    import jax.numpy as jnp

    rng = np.random.default_rng(n + 1)
    x = rng.normal(size=(3, 4, n)).astype(np.float32)
    got = np.asarray(hu.fwht_jnp(jnp.asarray(x)))
    want = hu.fwht(x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(st.integers(0, 6), st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_fwht_involution_pow2(p, seed):
    # H (H x) = n x for 2^p sizes
    n = 2**p
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(2, n))
    y = hu.fwht(hu.fwht(x))
    np.testing.assert_allclose(y, n * x, rtol=1e-6, atol=1e-8)


def test_energy_preservation():
    rng = np.random.default_rng(0)
    for n in (96, 320):
        x = rng.normal(size=(7, n))
        y = hu.fwht(x)
        np.testing.assert_allclose(
            (y**2).sum(axis=-1), n * (x**2).sum(axis=-1), rtol=1e-6
        )


@pytest.mark.parametrize("n", [8, 64, 96, 128, 160, 192, 256, 320])
def test_ifwht_inverts_fwht(n):
    """regression: Paley bases are not symmetric — the inverse must use
    Hᵀ, or every d ∈ {96, 160, 192, 320} QuaRot path corrupts."""
    import jax.numpy as jnp

    rng = np.random.default_rng(n)
    x = rng.normal(size=(4, n)).astype(np.float32)
    back = np.asarray(hu.ifwht_jnp(hu.fwht_jnp(jnp.asarray(x))))
    np.testing.assert_allclose(back, x, rtol=1e-4, atol=1e-4)


def test_outlier_spreading():
    """The paper's motivation: a channel spike spreads to ~uniform."""
    n = 256
    x = np.zeros((1, n), np.float32)
    x[0, 13] = 100.0
    y = hu.fwht(x)
    assert np.abs(y).max() <= 100.0 + 1e-3       # no amplification of a spike
    assert np.abs(y).min() >= 100.0 - 1e-3       # perfectly spread (|·| = 100)
