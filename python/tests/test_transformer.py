"""Pythia-like Transformer baseline: KV-cache decode consistency,
quantization path, and SmoothQuant folding."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as dm
from compile import transformer as tr

CFG = tr.TransformerTier("ptiny", "Pythia-tiny", d_model=32, n_layer=2, n_head=2, max_ctx=64)


@pytest.fixture(scope="module")
def setup():
    params = {k: jnp.asarray(v) for k, v in tr.init_params(CFG, seed=3).items()}
    lm, _ = dm.make_corpora()
    stream = dm.token_stream(lm, 4000, seed=9)
    return params, stream


def test_shapes(setup):
    params, stream = setup
    toks = jnp.asarray(stream[None, :16].astype(np.int32))
    logits, k, v = tr.forward_fp(CFG, params, toks)
    assert logits.shape == (1, 16, 256)
    assert k.shape == (2, 1, 64, 2, 16)


def test_prefill_decode_consistency(setup):
    """prefill T then decode steps == prefill T+k (the KV-cache chain
    the Fig 1b bench drives)."""
    params, stream = setup
    toks = jnp.asarray(stream[None, :20].astype(np.int32))
    full, _, _ = tr.forward_fp(CFG, params, toks)
    l8, k, v = tr.forward_fp(CFG, params, toks[:, :16])
    outs = []
    for i in range(16, 20):
        li, k, v = tr.forward_fp(CFG, params, toks[:, i : i + 1], k, v, cache_len=i)
        outs.append(np.asarray(li[:, 0]))
    np.testing.assert_allclose(np.stack(outs, 1), np.asarray(full[:, 16:]),
                               rtol=2e-3, atol=2e-4)


def test_causality(setup):
    params, stream = setup
    t1 = stream[:16].astype(np.int32).copy()
    t2 = t1.copy()
    t2[-1] = (t2[-1] + 7) % 250 + 4
    l1, _, _ = tr.forward_fp(CFG, params, jnp.asarray(t1[None]))
    l2, _, _ = tr.forward_fp(CFG, params, jnp.asarray(t2[None]))
    np.testing.assert_allclose(np.asarray(l1[:, :-1]), np.asarray(l2[:, :-1]), rtol=1e-5)
    assert np.abs(np.asarray(l1[:, -1]) - np.asarray(l2[:, -1])).max() > 1e-3


@pytest.mark.parametrize("alpha", [None, 0.5])
def test_quantized_close_to_fp(setup, alpha):
    params, stream = setup
    np_params = {k: np.asarray(v) for k, v in params.items()}
    wq, wsc, asc = tr.calibrate_and_quantize(
        CFG, np_params, stream, "w8a8", n_samples=8, seqlen=32, smooth_alpha=alpha)
    wq = {k: jnp.asarray(v) for k, v in wq.items()}
    toks = jnp.asarray(stream[None, :24].astype(np.int32))
    fp, _, _ = tr.forward_fp(CFG, params, toks)
    q, _, _ = tr.forward_q(CFG, "w8a8", None, wq, wsc, asc, toks)
    agree = (np.argmax(np.asarray(q), -1) == np.argmax(np.asarray(fp), -1)).mean()
    # attention tensors are robust to W8A8 (the paper's Fig 10 claim)
    assert agree > 0.7, f"alpha={alpha}: top-1 agreement {agree}"


def test_jamba_forward_and_combos():
    """Jamba hybrid: fp forward finite; each Table 4 combo jittable and
    finite; fp/fp/fp combo equals plain forward."""
    import jax

    from compile import jamba as jm

    cfg = jm.JambaTier("jt", d_model=32, n_layer=2, n_head=2)
    params = jm.init_params(cfg, seed=1)
    lm, _ = dm.make_corpora()
    stream = dm.token_stream(lm, 3000, seed=4)
    toks = jnp.asarray(stream[None, :24].astype(np.int32))
    P = {k: jnp.asarray(v) for k, v in params.items()}
    base = jm.forward_fp(cfg, P, toks)
    assert np.isfinite(np.asarray(base)).all()
    sites, chan = jm.calibrate(cfg, params, stream, n_samples=8, seqlen=24)
    fwd = jm.build_combo(cfg, params, sites, chan, "fp", "fp", "fp")
    np.testing.assert_allclose(np.asarray(fwd(toks)), np.asarray(base), rtol=1e-4, atol=1e-4)
    for combo in jm.TABLE4_COMBOS[1:]:
        f = jm.build_combo(cfg, params, sites, chan, *combo)
        out = jax.jit(f)(toks)
        assert np.isfinite(np.asarray(out)).all(), combo


def test_moe_top_k_mass():
    """router keeps exactly top-k experts with renormalized weights."""
    from compile import jamba as jm

    cfg = jm.JambaTier("jt", d_model=16, n_layer=1, n_head=2, n_experts=4, top_k=2)
    params = {k: jnp.asarray(v) for k, v in jm.init_params(cfg, seed=2).items()}
    h = jnp.asarray(np.random.default_rng(0).normal(size=(1, 4, 16)), jnp.float32)
    out = jm._moe_block(cfg, params, "layers.0.", h)
    assert np.isfinite(np.asarray(out)).all()
