"""Theorem 4.1 / paper §A: quantization error of a stable discrete LTI
SSM stays bounded over time (the python half of the Figure 5
experiment; the HiPPO-materialized rust version lives in
rust/src/ssm/hippo.rs and benches/fig5_error_bound)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st


def run_lti(a_diag, b, c, xs, T):
    """diagonal stable LTI: h[t] = diag(a) h[t-1] + b x[t]; y = c·h."""
    n = len(a_diag)
    h = np.zeros(n)
    ys = []
    for t in range(T):
        h = a_diag * h + b * xs[t]
        ys.append(c @ h)
    return np.array(ys)


@given(st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_error_bounded_for_stable_system(seed):
    rng = np.random.default_rng(seed)
    n, T = 4, 100
    a = np.exp(-rng.uniform(0.05, 1.0, n))      # |a| < 1: stable
    b = rng.normal(0, 1, n)
    c = rng.normal(0, 1, n)
    xs = rng.normal(0, 1, (T, n))
    s = np.abs(xs).max() / 127
    xq = np.clip(np.round(xs / s), -127, 127) * s
    eps = s / 2
    err = np.abs(run_lti(a, b, c, xs, T) - run_lti(a, b, c, xq, T))
    # geometric-series bound: |err| ≤ ε·|b|·|c|·n / (1 - a_max)
    bound = eps * np.abs(b).max() * np.abs(c).sum() * 1.0 / (1 - a.max())
    assert (err <= bound + 1e-9).all(), f"max err {err.max()} bound {bound}"


def test_error_does_not_grow_with_time():
    rng = np.random.default_rng(1)
    n, T = 4, 400
    a = np.exp(-rng.uniform(0.1, 1.0, n))
    b = rng.normal(0, 1, n)
    c = rng.normal(0, 1, n)
    xs = rng.normal(0, 1, (T, n))
    s = np.abs(xs).max() / 127
    xq = np.clip(np.round(xs / s), -127, 127) * s
    err = np.abs(run_lti(a, b, c, xs, T) - run_lti(a, b, c, xq, T))
    head = err[: T // 4].max()
    tail = err[-T // 4 :].max()
    assert tail < 5 * head + 1e-9, "error must not accumulate over steps"


def test_unstable_system_would_diverge():
    """sanity contrast: with |a| > 1 the same bound logic fails — shows
    the theorem's stability premise is load-bearing."""
    n, T = 2, 60
    a = np.array([1.08, 1.05])
    b = np.ones(n)
    c = np.ones(n)
    rng = np.random.default_rng(2)
    xs = rng.normal(0, 1, (T, n))
    s = np.abs(xs).max() / 127
    xq = np.clip(np.round(xs / s), -127, 127) * s
    err = np.abs(run_lti(a, b, c, xs, T) - run_lti(a, b, c, xq, T))
    assert err[-1] > 10 * err[: T // 4].max()


def test_selective_scan_error_bounded_in_practice():
    """the selective (time-varying) case the paper actually quantizes:
    errors at the SSM output stay bounded when Δ·A < 0."""
    import jax.numpy as jnp

    from compile.kernels import ref
    from compile.quant import core as qc

    rng = np.random.default_rng(3)
    Bb, T, Di, N = 1, 200, 8, 4
    x = rng.normal(size=(Bb, T, Di)).astype(np.float32)
    dt = (0.01 + 0.2 * rng.random((Bb, T, Di))).astype(np.float32)
    A = -(0.5 + rng.random((Di, N))).astype(np.float32)
    B = rng.normal(size=(Bb, T, N)).astype(np.float32)
    C = rng.normal(size=(Bb, T, N)).astype(np.float32)
    D = rng.normal(size=Di).astype(np.float32)
    y0, _ = ref.selective_scan(*map(jnp.asarray, (x, dt, A, B, C, D)))
    s = np.abs(x).max() / 127
    xq = np.clip(np.round(x / s), -127, 127) * s
    y1, _ = ref.selective_scan(*map(jnp.asarray, (xq, dt, A, B, C, D)))
    err = np.abs(np.asarray(y0) - np.asarray(y1)).mean(axis=(0, 2))
    assert err[-50:].max() < 10 * (err[:50].max() + 1e-6)
