//! Quickstart: load the artifacts, pick the smallest tier, generate a
//! few tokens with the FP and the Quamba W8A8 model, and print the
//! latency + memory comparison.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use quamba::config::Manifest;
use quamba::coordinator::server::ServerHandle;
use quamba::coordinator::{EngineConfig, SamplingParams};
use quamba::data;

fn main() -> Result<()> {
    let root = Manifest::default_root();
    let mani = Manifest::load(&root).map_err(anyhow::Error::msg)?;
    let tier = mani
        .tiers
        .keys()
        .find(|t| *t != "jamba")
        .cloned()
        .expect("no tiers built — run `make artifacts`");
    println!("tier: {tier} ({})", mani.tiers[&tier].paper_name);

    let stream = data::load_stream(&mani.data["pile_eval"])?;
    let vocab = data::Vocab::load(&mani.data["vocab"])?;
    let prompt = stream[..24].to_vec();
    println!("prompt: {}\n", vocab.decode(&prompt));

    for method in ["fp16", "quamba"] {
        let mut server = ServerHandle::spawn(root.clone(), EngineConfig::new(&tier, method))?;
        let rx = server.submit(
            prompt.clone(),
            32,
            SamplingParams { temperature: 0.8, top_k: 20, seed: 1, ..Default::default() },
        );
        let resp = rx.recv()?;
        let bytes = mani
            .weights
            .get(&format!("{tier}_{method}"))
            .map(|w| w.bytes as f64 / 1e6)
            .unwrap_or(f64::NAN);
        println!("[{method:>7}] {}", vocab.decode(&resp.tokens));
        println!(
            "          TTFT {:.1} ms · TPOT {:.2} ms · model {bytes:.2} MB\n",
            resp.ttft_ms, resp.tpot_ms
        );
        server.shutdown();
    }
    Ok(())
}
