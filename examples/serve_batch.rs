//! Batched-serving scenario (the paper's "request-intensive cloud"
//! motivation): Poisson arrivals into the threaded server, continuous
//! bucketed decode batching, TTFT/TPOT/TTLT + throughput report,
//! FP vs Quamba side by side.
//!
//! Two backends share the identical front door:
//!   * `--backend xla`     AOT-compiled graphs (`make artifacts` first)
//!   * `--backend native`  the artifact-free pure-rust engine: an fp32
//!                         reference model and its calibrated W8A8
//!                         counterpart, synthesized on the spot — the
//!                         "edge serving from a bare machine" story
//! Default is `auto`: XLA when an artifact tree is present, else native.
//!
//!     cargo run --release --example serve_batch -- [--requests 24] [--rate 8] [--backend native] [--threads 4] [--kernels avx2] [--bits 8] [--cache-mb 8] [--snapshot-stride 64] [--shared-prefix 32] [--prefill-chunk 64] [--max-tokens-per-tick 0] [--burst 2] [--fault-rate 0.02] [--fault-seed 1]
//!
//! `--threads N` (native backend) runs decode rounds on N scoped
//! workers — token streams are bit-identical to `--threads 1`.
//! `--kernels scalar|avx2|neon` forces the int8 kernel dispatch (also
//! settable process-wide via `QUAMBA_KERNELS`); tokens are
//! bit-identical across backends, only latency moves.
//! `--bits 4` (native backend) serves the packed-nibble W4A8 tier
//! instead of W8A8: half the GEMM weight bytes, per-group scales,
//! activations still int8 — the quantized arm's label becomes
//! `quamba-w4a8`.
//! `--cache-mb M` (native backend, 0 = off) arms the prefix-sharing
//! state cache with an M-megabyte snapshot budget and
//! `--snapshot-stride N` interior cut points; `--shared-prefix L`
//! prepends the same L-token system prompt to every request so the
//! warm-TTFT effect is visible — the end-of-run report gains a
//! `prefix-cache` line (hit rate, bytes, prefill tokens saved).
//! Cached-path tokens are bit-identical to cache-off serving.
//!
//! `--prefill-chunk C` / `--max-tokens-per-tick B` drive the unified
//! chunked-prefill scheduler (0 = unchunked / unlimited): long prompts
//! advance C tokens per tick instead of stalling live decode lanes —
//! again latency-only, tokens never move.
//! `--burst N` (native backend) switches to the head-of-line-blocking
//! scenario the chunking exists for: N long prompts
//! (`--burst-prompt-len`, default 1024) arrive while short requests
//! are mid-decode; the run reports each configuration's **max
//! observed inter-token gap** for the already-decoding requests,
//! chunked vs unchunked side by side.
//!
//! `--fault-rate P` (native backend, with `--fault-seed S`, default 1)
//! arms the deterministic fault-injection plan from
//! `coordinator/faults.rs`: seeded decode/prefill panics, admission
//! alloc failures, snapshot corruption and tick latency at rate P.
//! Faulted requests fail alone with typed reasons; the end-of-run
//! report (also under `--burst`) gains a `failures` line with the
//! rejected/deadline/cancelled/failed counters and the shed rate —
//! the live demo of `docs/ARCHITECTURE.md` §7.

use anyhow::Result;
use quamba::bench_support::{burst_itl_max_report, Workload};
use quamba::config::Manifest;
use quamba::coordinator::faults::silence_injected_panics;
use quamba::coordinator::server::ServerHandle;
use quamba::coordinator::{EngineConfig, FaultPlan, NativeEngineConfig, SamplingParams};
use quamba::data;
use quamba::quant::{KernelBackend, Kernels};
use quamba::ssm::{MambaModel, MambaTier, QuantConfig, QuantizedMambaModel, StepModel};
use quamba::util::cli::Args;
use quamba::util::rng::Pcg32;

fn main() -> Result<()> {
    let args = Args::from_env(&[]);
    let n = args.get_usize("requests", 24);
    let rate = args.get_f64("rate", 8.0);
    let max_new = args.get_usize("max-new", 24);
    let backend = args.get_or("backend", "auto").to_string();
    let use_xla = match backend.as_str() {
        "xla" => true,
        "native" => false,
        _ => Manifest::load(&Manifest::default_root()).is_ok(),
    };
    if use_xla {
        serve_xla(&args, n, rate, max_new)
    } else {
        serve_native(&args, n, rate, max_new)
    }
}

/// Feed the Poisson workload into a running server; returns
/// (completed, wall seconds, metrics report). With an armed prefix
/// cache, appends a one-line hit/bytes summary from the engine thread.
fn drive(mut server: ServerHandle, wl: &Workload, max_new: usize) -> (usize, f64, Option<String>) {
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::new();
    for (i, prompt) in wl.prompts.iter().enumerate() {
        let target = wl.arrival_s[i];
        let now = t0.elapsed().as_secs_f64();
        if target > now {
            std::thread::sleep(std::time::Duration::from_secs_f64(target - now));
        }
        rxs.push(server.submit(prompt.clone(), max_new, SamplingParams::default()));
    }
    // count clean finishes only — shed/cancelled/failed requests are
    // still answered (typed), and show up on the report's failures line
    let done = rxs
        .into_iter()
        .filter(|rx| rx.recv().map(|r| r.finish.is_ok()).unwrap_or(false))
        .count();
    let wall = t0.elapsed().as_secs_f64();
    let mut report = server.metrics_report();
    if let Some(c) = server.cache_stats() {
        let line = format!(
            "cache summary: {:.0}% hit rate, {} prefill tokens saved, {}/{} bytes",
            100.0 * c.hit_rate(),
            c.prefill_tokens_saved,
            c.bytes_in_use,
            c.capacity_bytes
        );
        report = Some(match report {
            Some(r) => format!("{r}\n{line}"),
            None => line,
        });
    }
    server.shutdown();
    (done, wall, report)
}

fn serve_xla(args: &Args, n: usize, rate: f64, max_new: usize) -> Result<()> {
    let root = Manifest::default_root();
    let mani = Manifest::load(&root).map_err(anyhow::Error::msg)?;
    // prefer the tier with wide decode buckets (m2p8 in the full build)
    let tier = args
        .get("tier")
        .map(String::from)
        .or_else(|| {
            mani.graphs
                .values()
                .filter(|g| g.kind == "decode" && g.batch > 1)
                .map(|g| g.tier.clone())
                .next()
        })
        .or_else(|| mani.tiers.keys().next().cloned())
        .expect("no artifacts");
    let stream = data::load_stream(&mani.data["pile_eval"])?;
    let wl = Workload::poisson(&stream, n, rate, 8, 40, max_new, 7);

    for method in ["fp16", "quamba"] {
        if !mani
            .graphs
            .values()
            .any(|g| g.tier == tier && g.method == method && g.kind == "decode")
        {
            continue;
        }
        println!("\n=== xla {tier}/{method}: {n} requests, ~{rate}/s, {max_new} new tokens each ===");
        let server = ServerHandle::spawn(root.clone(), EngineConfig::new(&tier, method))?;
        let (done, wall, report) = drive(server, &wl, max_new);
        println!("completed {done}/{n} in {wall:.2}s");
        if let Some(r) = report {
            println!("{r}");
        }
    }
    Ok(())
}

/// `--bits 8|4` → the projection/head weight width for the quantized
/// arm (8 = W8A8 per-tensor int8, 4 = W4A8 packed nibble with
/// per-group scales; activations stay int8 either way).
fn weight_bits(args: &Args) -> u8 {
    match args.get_usize("bits", 8) {
        8 => 8,
        4 => 4,
        b => panic!("--bits {b}: supported weight widths are 8 (W8A8) and 4 (W4A8)"),
    }
}

/// `--fault-rate P` / `--fault-seed S` → a seeded [`FaultPlan`]
/// (disabled at rate 0, the default). Arming it also installs the
/// panic-hook filter so injected panics don't spray backtraces over
/// the serving report — they surface as typed `Failed` responses and
/// the report's `failures` line instead.
fn fault_plan(args: &Args) -> FaultPlan {
    let rate = args.get_f64("fault-rate", 0.0);
    if rate <= 0.0 {
        return FaultPlan::none();
    }
    let seed = args.get_usize("fault-seed", 1) as u64;
    silence_injected_panics();
    println!(
        "fault injection: seed {seed}, rate {rate:.3} \
         (deterministic per (site, request, step); failures are typed, survivors bit-identical)"
    );
    FaultPlan::seeded(seed, rate)
}

/// `--burst N`: the scenario the unified chunked-prefill scheduler
/// exists for, measured directly — same workload, chunked vs
/// unchunked, reporting max inter-token gap of the live decode lanes.
/// The harness is `bench_support::burst_itl_max`, the exact workload
/// the CI trajectory key `burst_itl_max` tracks.
fn serve_burst(args: &Args, tier: &MambaTier) -> Result<()> {
    let seed = args.get_usize("seed", 7) as u64;
    let burst_n = args.get_usize("burst", 2);
    let burst_len = args.get_usize("burst-prompt-len", 1024);
    let chunk = match args.get_usize("prefill-chunk", 64) {
        // 0 means "unchunked", which is already the comparison's other
        // arm — comparing unchunked against itself would be vacuous
        0 => {
            println!("--prefill-chunk 0 is the unchunked arm itself; comparing chunk=64 instead");
            64
        }
        c => c,
    };
    let n_dec = args.get_usize("requests", 4).min(8);
    let max_new = args.get_usize("max-new", 64);
    // honor the same engine knobs the normal serving path takes — the
    // comparison varies ONLY prefill_chunk
    let base_cfg = NativeEngineConfig {
        threads: args.get_usize("threads", 1),
        kernel_backend: args.get("kernels").filter(|v| *v != "auto").map(|v| {
            KernelBackend::parse(v)
                .unwrap_or_else(|| panic!("--kernels {v}: unknown backend (auto|scalar|avx2|neon)"))
        }),
        cache_bytes: args.get_mb("cache-mb", 0.0),
        snapshot_stride: args.get_usize("snapshot-stride", 64),
        max_tokens_per_tick: args.get_usize("max-tokens-per-tick", 0),
        faults: fault_plan(args),
        ..Default::default()
    };
    let faults_on = base_cfg.faults.enabled();
    let bits = weight_bits(args);
    println!(
        "burst scenario: {n_dec} decoding requests, then {burst_n}×{burst_len}-token prompts \
         arriving mid-decode (W{bits}A8, tier {})",
        tier.name
    );
    let mut gaps = Vec::new();
    for (label, pc) in [(format!("prefill_chunk={chunk}"), chunk), ("unchunked".to_string(), 0)] {
        // fresh identically-seeded model per run: both configurations
        // serve the same weights and the same request stream
        let model = MambaModel::synthetic(tier.clone(), seed);
        let mut rng = Pcg32::new(seed ^ 0x5EED);
        let calib: Vec<u16> =
            (0..512).map(|_| rng.below(tier.vocab as u32) as u16).collect();
        let qcfg = QuantConfig { weight_bits: bits, ..QuantConfig::default() };
        let qmodel = QuantizedMambaModel::from_model(&model, &calib, &qcfg);
        let cfg =
            NativeEngineConfig { prefill_chunk: pc, weight_bits: bits, ..base_cfg.clone() };
        let (gap, report) =
            burst_itl_max_report(Box::new(qmodel), cfg, n_dec, max_new, burst_n, burst_len, seed)?;
        println!("  {label:<20} max inter-token gap = {gap:.3} ms");
        if faults_on {
            // the failure counters + shed rate for this arm
            for line in report.lines() {
                println!("    {line}");
            }
        }
        gaps.push(gap);
    }
    println!(
        "chunking {} head-of-line blocking ({:.3} ms vs {:.3} ms; tokens are identical \
         in both runs — only latency moves)",
        if gaps[0] < gaps[1] { "bounded" } else { "did NOT bound" },
        gaps[0],
        gaps[1]
    );
    Ok(())
}

/// Artifact-free serving: synthesize a tier, calibrate a W8A8 model
/// from the fp32 reference, and serve both through the same loop.
fn serve_native(args: &Args, n: usize, rate: f64, max_new: usize) -> Result<()> {
    let seed = args.get_usize("seed", 7) as u64;
    let tier = MambaTier {
        name: "edge64".into(),
        d_model: 64,
        n_layer: 4,
        d_state: 8,
        d_conv: 4,
        d_inner: 128,
        dt_rank: 8,
        vocab: 256,
    };
    if args.get_usize("burst", 0) > 0 {
        return serve_burst(args, &tier);
    }
    let bits = weight_bits(args);
    let model = MambaModel::synthetic(tier.clone(), seed);
    let mut rng = Pcg32::new(seed ^ 0x5EED);
    let calib: Vec<u16> = (0..512).map(|_| rng.below(tier.vocab as u32) as u16).collect();
    let qcfg = QuantConfig { weight_bits: bits, ..QuantConfig::default() };
    let qmodel = QuantizedMambaModel::from_model(&model, &calib, &qcfg);
    let qname = if bits == 4 { "quamba-w4a8" } else { "quamba-w8a8" };
    println!(
        "native tier {}: d_model={} n_layer={} d_inner={} | W{bits}A8 weights {:.1} KiB \
         ({:.1} KiB in GEMMs{})",
        tier.name,
        tier.d_model,
        tier.n_layer,
        tier.d_inner,
        qmodel.weight_bytes_i8() as f64 / 1024.0,
        qmodel.gemm_weight_bytes() as f64 / 1024.0,
        if bits == 4 { ", packed nibble + per-group scales" } else { ", int8" },
    );
    let stream: Vec<u16> = (0..4096).map(|_| rng.below(tier.vocab as u32) as u16).collect();
    let mut wl = Workload::poisson(&stream, n, rate, 8, 40, max_new, 7);
    // shared system prompt: the prefix-cache demo workload — every
    // request pays its prefill once, the rest hit the trie
    let shared_prefix = args.get_usize("shared-prefix", 0);
    if shared_prefix > 0 {
        let prefix: Vec<u16> =
            (0..shared_prefix).map(|_| rng.below(tier.vocab as u32) as u16).collect();
        for p in wl.prompts.iter_mut() {
            let mut with = prefix.clone();
            with.extend_from_slice(p);
            *p = with;
        }
    }

    let threads = args.get_usize("threads", 1);
    let kernel_backend = args.get("kernels").filter(|v| *v != "auto").map(|v| {
        KernelBackend::parse(v)
            .unwrap_or_else(|| panic!("--kernels {v}: unknown backend (auto|scalar|avx2|neon)"))
    });
    let kers = match kernel_backend {
        Some(b) => Kernels::for_backend(b),
        None => Kernels::auto(),
    };
    println!("int8 kernel dispatch: {} (override with --kernels / QUAMBA_KERNELS)", kers.label());
    let cache_bytes = args.get_mb("cache-mb", 0.0);
    let snapshot_stride = args.get_usize("snapshot-stride", 64);
    if cache_bytes > 0 {
        println!(
            "prefix cache: {:.1} MB budget, snapshot stride {snapshot_stride} \
             (tokens are bit-identical to --cache-mb 0)",
            cache_bytes as f64 / 1e6
        );
    }
    let prefill_chunk = args.get_usize("prefill-chunk", 64);
    let max_tokens_per_tick = args.get_usize("max-tokens-per-tick", 0);
    println!(
        "scheduler: prefill_chunk={prefill_chunk} max_tokens_per_tick={max_tokens_per_tick} \
         (0 = unchunked/unlimited; chunking moves latency, never tokens)"
    );
    let faults = fault_plan(args);
    let backends: Vec<(&str, u8, Box<dyn StepModel + Send + Sync>)> =
        vec![("fp32", 32, Box::new(model)), (qname, bits, Box::new(qmodel))];
    for (name, wb, m) in backends {
        println!(
            "\n=== native {}/{name}: {n} requests, ~{rate}/s, {max_new} new tokens each ===",
            tier.name
        );
        let server = ServerHandle::spawn_native(
            m,
            NativeEngineConfig {
                threads,
                kernel_backend,
                cache_bytes,
                snapshot_stride,
                prefill_chunk,
                max_tokens_per_tick,
                faults: faults.clone(),
                weight_bits: wb,
                ..Default::default()
            },
        )?;
        let (done, wall, report) = drive(server, &wl, max_new);
        println!("completed {done}/{n} in {wall:.2}s");
        if let Some(r) = report {
            println!("{r}");
        }
    }
    Ok(())
}
