//! Batched-serving scenario (the paper's "request-intensive cloud"
//! motivation): Poisson arrivals into the threaded server, continuous
//! bucketed decode batching, TTFT/TPOT/TTLT + throughput report,
//! FP vs Quamba side by side.
//!
//! Two backends share the identical front door:
//!   * `--backend xla`     AOT-compiled graphs (`make artifacts` first)
//!   * `--backend native`  the artifact-free pure-rust engine: an fp32
//!                         reference model and its calibrated W8A8
//!                         counterpart, synthesized on the spot — the
//!                         "edge serving from a bare machine" story
//! Default is `auto`: XLA when an artifact tree is present, else native.
//!
//!     cargo run --release --example serve_batch -- [--requests 24] [--rate 8] [--backend native] [--threads 4] [--kernels avx2] [--bits 8] [--spec-tokens 4] [--spec-draft w4a8] [--cache-mb 8] [--snapshot-stride 64] [--shared-prefix 32] [--prefill-chunk 64] [--max-tokens-per-tick 0] [--burst 2] [--fault-rate 0.02] [--fault-seed 1] [--verbose] [--trace-out FILE] [--manual-clock MS]
//!
//! `--threads N` (native backend) runs decode rounds on N scoped
//! workers — token streams are bit-identical to `--threads 1`.
//! `--kernels scalar|avx2|neon` forces the int8 kernel dispatch (also
//! settable process-wide via `QUAMBA_KERNELS`); tokens are
//! bit-identical across backends, only latency moves.
//! `--bits 4` (native backend) serves the packed-nibble W4A8 tier
//! instead of W8A8: half the GEMM weight bytes, per-group scales,
//! activations still int8 — the quantized arm's label becomes
//! `quamba-w4a8`.
//! `--spec-tokens K` (native backend, 0 = off) arms self-speculative
//! decoding: a cheap draft twin (`--spec-draft w4a8|fp32`, default
//! w4a8) proposes K tokens per decoding lane and the target verifies
//! all of them in one batched prefill, rolling the lane's O(1) SSM
//! state snapshot back on the first rejection — token streams stay
//! bit-identical to `--spec-tokens 0`, only throughput moves. The
//! report gains a `spec` line with rounds and mean acceptance length.
//! `--cache-mb M` (native backend, 0 = off) arms the prefix-sharing
//! state cache with an M-megabyte snapshot budget and
//! `--snapshot-stride N` interior cut points; `--shared-prefix L`
//! prepends the same L-token system prompt to every request so the
//! warm-TTFT effect is visible — the end-of-run report gains a
//! `prefix-cache` line (hit rate, bytes, prefill tokens saved).
//! Cached-path tokens are bit-identical to cache-off serving.
//!
//! `--prefill-chunk C` / `--max-tokens-per-tick B` drive the unified
//! chunked-prefill scheduler (0 = unchunked / unlimited): long prompts
//! advance C tokens per tick instead of stalling live decode lanes —
//! again latency-only, tokens never move.
//! `--burst N` (native backend) switches to the head-of-line-blocking
//! scenario the chunking exists for: N long prompts
//! (`--burst-prompt-len`, default 1024) arrive while short requests
//! are mid-decode; the run reports each configuration's **max
//! observed inter-token gap** for the already-decoding requests,
//! chunked vs unchunked side by side.
//!
//! `--fault-rate P` (native backend, with `--fault-seed S`, default 1)
//! arms the deterministic fault-injection plan from
//! `coordinator/faults.rs`: seeded decode/prefill panics, admission
//! alloc failures, snapshot corruption and tick latency at rate P.
//! Faulted requests fail alone with typed reasons; the end-of-run
//! report (also under `--burst`) gains a `failures` line with the
//! rejected/deadline/cancelled/failed counters and the shed rate —
//! the live demo of `docs/ARCHITECTURE.md` §7.
//!
//! Observability (docs/ARCHITECTURE.md §8): `--verbose` prints every
//! response's per-request timeline (queued → admitted → first token →
//! finished, all on the engine clock); `--trace-out FILE` arms the
//! flight recorder and dumps Chrome trace-event JSON on drain;
//! `--manual-clock MS` (native backend) runs the whole workload on
//! `Clock::Manual` — timestamps advance MS per tick instead of
//! reading the wall clock, requests are submitted up-front, and two
//! identically-seeded runs produce **byte-identical** trace dumps and
//! equal metrics snapshots.

use anyhow::Result;
use quamba::bench_support::{burst_itl_max_report, Workload};
use quamba::config::Manifest;
use quamba::coordinator::faults::silence_injected_panics;
use quamba::coordinator::server::ServerHandle;
use quamba::coordinator::{EngineConfig, FaultPlan, NativeEngineConfig, SamplingParams, SpecDraft};
use quamba::data;
use quamba::quant::{KernelBackend, Kernels};
use quamba::ssm::{MambaModel, MambaTier, QuantConfig, QuantizedMambaModel, StepModel};
use quamba::util::cli::Args;
use quamba::util::rng::Pcg32;

fn main() -> Result<()> {
    let args = Args::from_env(&["verbose"]);
    let n = args.get_usize("requests", 24);
    let rate = args.get_f64("rate", 8.0);
    let max_new = args.get_usize("max-new", 24);
    let backend = args.get_or("backend", "auto").to_string();
    let use_xla = match backend.as_str() {
        "xla" => true,
        "native" => false,
        _ => Manifest::load(&Manifest::default_root()).is_ok(),
    };
    if use_xla {
        serve_xla(&args, n, rate, max_new)
    } else {
        serve_native(&args, n, rate, max_new)
    }
}

/// Feed the Poisson workload into a running server; returns
/// (completed, wall seconds, metrics report). With an armed prefix
/// cache, appends a one-line hit/bytes summary from the engine thread.
/// `--verbose` prints every response's per-request timeline and
/// `--trace-out FILE` dumps the flight recorder before shutdown.
fn drive(
    mut server: ServerHandle,
    wl: &Workload,
    max_new: usize,
    args: &Args,
) -> (usize, f64, Option<String>) {
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::new();
    for (i, prompt) in wl.prompts.iter().enumerate() {
        let target = wl.arrival_s[i];
        let now = t0.elapsed().as_secs_f64();
        if target > now {
            std::thread::sleep(std::time::Duration::from_secs_f64(target - now));
        }
        rxs.push(server.submit(prompt.clone(), max_new, SamplingParams::default()));
    }
    // count clean finishes only — shed/cancelled/failed requests are
    // still answered (typed), and show up on the report's failures line
    let mut responses: Vec<_> = rxs.into_iter().filter_map(|rx| rx.recv().ok()).collect();
    let done = responses.iter().filter(|r| r.finish.is_ok()).count();
    let wall = t0.elapsed().as_secs_f64();
    if args.has("verbose") {
        responses.sort_by_key(|r| r.id);
        for r in &responses {
            println!("{}", r.timeline());
        }
    }
    let mut report = server.metrics_report();
    if let Some(c) = server.cache_stats() {
        let line = format!(
            "cache summary: {:.0}% hit rate, {} prefill tokens saved, {}/{} bytes",
            100.0 * c.hit_rate(),
            c.prefill_tokens_saved,
            c.bytes_in_use,
            c.capacity_bytes
        );
        report = Some(match report {
            Some(r) => format!("{r}\n{line}"),
            None => line,
        });
    }
    if let Some(path) = args.get("trace-out") {
        match server.dump_trace() {
            Some(json) => {
                if let Err(e) = std::fs::write(path, &json) {
                    eprintln!("trace: failed to write {path}: {e}");
                } else {
                    println!("trace: wrote {} bytes of Chrome trace JSON to {path}", json.len());
                }
            }
            None => println!("trace: this backend has no flight recorder"),
        }
    }
    server.shutdown();
    (done, wall, report)
}

fn serve_xla(args: &Args, n: usize, rate: f64, max_new: usize) -> Result<()> {
    let root = Manifest::default_root();
    let mani = Manifest::load(&root).map_err(anyhow::Error::msg)?;
    // prefer the tier with wide decode buckets (m2p8 in the full build)
    let tier = args
        .get("tier")
        .map(String::from)
        .or_else(|| {
            mani.graphs
                .values()
                .filter(|g| g.kind == "decode" && g.batch > 1)
                .map(|g| g.tier.clone())
                .next()
        })
        .or_else(|| mani.tiers.keys().next().cloned())
        .expect("no artifacts");
    let stream = data::load_stream(&mani.data["pile_eval"])?;
    let wl = Workload::poisson(&stream, n, rate, 8, 40, max_new, 7);

    for method in ["fp16", "quamba"] {
        if !mani
            .graphs
            .values()
            .any(|g| g.tier == tier && g.method == method && g.kind == "decode")
        {
            continue;
        }
        println!("\n=== xla {tier}/{method}: {n} requests, ~{rate}/s, {max_new} new tokens each ===");
        let server = ServerHandle::spawn(root.clone(), EngineConfig::new(&tier, method))?;
        let (done, wall, report) = drive(server, &wl, max_new, args);
        println!("completed {done}/{n} in {wall:.2}s");
        if let Some(r) = report {
            println!("{r}");
        }
    }
    Ok(())
}

/// `--bits 8|4` → the projection/head weight width for the quantized
/// arm (8 = W8A8 per-tensor int8, 4 = W4A8 packed nibble with
/// per-group scales; activations stay int8 either way).
fn weight_bits(args: &Args) -> u8 {
    match args.get_usize("bits", 8) {
        8 => 8,
        4 => 4,
        b => panic!("--bits {b}: supported weight widths are 8 (W8A8) and 4 (W4A8)"),
    }
}

/// `--fault-rate P` / `--fault-seed S` → a seeded [`FaultPlan`]
/// (disabled at rate 0, the default). Arming it also installs the
/// panic-hook filter so injected panics don't spray backtraces over
/// the serving report — they surface as typed `Failed` responses and
/// the report's `failures` line instead.
fn fault_plan(args: &Args) -> FaultPlan {
    let rate = args.get_f64("fault-rate", 0.0);
    if rate <= 0.0 {
        return FaultPlan::none();
    }
    let seed = args.get_usize("fault-seed", 1) as u64;
    silence_injected_panics();
    println!(
        "fault injection: seed {seed}, rate {rate:.3} \
         (deterministic per (site, request, step); failures are typed, survivors bit-identical)"
    );
    FaultPlan::seeded(seed, rate)
}

/// `--burst N`: the scenario the unified chunked-prefill scheduler
/// exists for, measured directly — same workload, chunked vs
/// unchunked, reporting max inter-token gap of the live decode lanes.
/// The harness is `bench_support::burst_itl_max`, the exact workload
/// the CI trajectory key `burst_itl_max` tracks.
fn serve_burst(args: &Args, tier: &MambaTier) -> Result<()> {
    let seed = args.get_usize("seed", 7) as u64;
    let burst_n = args.get_usize("burst", 2);
    let burst_len = args.get_usize("burst-prompt-len", 1024);
    let chunk = match args.get_usize("prefill-chunk", 64) {
        // 0 means "unchunked", which is already the comparison's other
        // arm — comparing unchunked against itself would be vacuous
        0 => {
            println!("--prefill-chunk 0 is the unchunked arm itself; comparing chunk=64 instead");
            64
        }
        c => c,
    };
    let n_dec = args.get_usize("requests", 4).min(8);
    let max_new = args.get_usize("max-new", 64);
    // honor the same engine knobs the normal serving path takes — the
    // comparison varies ONLY prefill_chunk
    let base_cfg = NativeEngineConfig {
        threads: args.get_usize("threads", 1),
        kernel_backend: args.get("kernels").filter(|v| *v != "auto").map(|v| {
            KernelBackend::parse(v)
                .unwrap_or_else(|| panic!("--kernels {v}: unknown backend (auto|scalar|avx2|neon)"))
        }),
        cache_bytes: args.get_mb("cache-mb", 0.0),
        snapshot_stride: args.get_usize("snapshot-stride", 64),
        max_tokens_per_tick: args.get_usize("max-tokens-per-tick", 0),
        faults: fault_plan(args),
        ..Default::default()
    };
    let faults_on = base_cfg.faults.enabled();
    let bits = weight_bits(args);
    println!(
        "burst scenario: {n_dec} decoding requests, then {burst_n}×{burst_len}-token prompts \
         arriving mid-decode (W{bits}A8, tier {})",
        tier.name
    );
    let mut gaps = Vec::new();
    for (label, pc) in [(format!("prefill_chunk={chunk}"), chunk), ("unchunked".to_string(), 0)] {
        // fresh identically-seeded model per run: both configurations
        // serve the same weights and the same request stream
        let model = MambaModel::synthetic(tier.clone(), seed);
        let mut rng = Pcg32::new(seed ^ 0x5EED);
        let calib: Vec<u16> =
            (0..512).map(|_| rng.below(tier.vocab as u32) as u16).collect();
        let qcfg = QuantConfig { weight_bits: bits, ..QuantConfig::default() };
        let qmodel = QuantizedMambaModel::from_model(&model, &calib, &qcfg);
        let cfg =
            NativeEngineConfig { prefill_chunk: pc, weight_bits: bits, ..base_cfg.clone() };
        let (gap, report) =
            burst_itl_max_report(Box::new(qmodel), cfg, n_dec, max_new, burst_n, burst_len, seed)?;
        println!("  {label:<20} max inter-token gap = {gap:.3} ms");
        if faults_on {
            // the failure counters + shed rate for this arm
            for line in report.lines() {
                println!("    {line}");
            }
        }
        gaps.push(gap);
    }
    println!(
        "chunking {} head-of-line blocking ({:.3} ms vs {:.3} ms; tokens are identical \
         in both runs — only latency moves)",
        if gaps[0] < gaps[1] { "bounded" } else { "did NOT bound" },
        gaps[0],
        gaps[1]
    );
    Ok(())
}

/// Artifact-free serving: synthesize a tier, calibrate a W8A8 model
/// from the fp32 reference, and serve both through the same loop.
fn serve_native(args: &Args, n: usize, rate: f64, max_new: usize) -> Result<()> {
    let seed = args.get_usize("seed", 7) as u64;
    let tier = MambaTier {
        name: "edge64".into(),
        d_model: 64,
        n_layer: 4,
        d_state: 8,
        d_conv: 4,
        d_inner: 128,
        dt_rank: 8,
        vocab: 256,
    };
    if args.get_usize("burst", 0) > 0 {
        return serve_burst(args, &tier);
    }
    if args.get("manual-clock").is_some() {
        return serve_manual_clock(args, &tier, n, max_new);
    }
    let bits = weight_bits(args);
    let model = MambaModel::synthetic(tier.clone(), seed);
    let mut rng = Pcg32::new(seed ^ 0x5EED);
    let calib: Vec<u16> = (0..512).map(|_| rng.below(tier.vocab as u32) as u16).collect();
    let qcfg = QuantConfig { weight_bits: bits, ..QuantConfig::default() };
    let qmodel = QuantizedMambaModel::from_model(&model, &calib, &qcfg);
    let qname = if bits == 4 { "quamba-w4a8" } else { "quamba-w8a8" };
    println!(
        "native tier {}: d_model={} n_layer={} d_inner={} | W{bits}A8 weights {:.1} KiB \
         ({:.1} KiB in GEMMs{})",
        tier.name,
        tier.d_model,
        tier.n_layer,
        tier.d_inner,
        qmodel.weight_bytes_i8() as f64 / 1024.0,
        qmodel.gemm_weight_bytes() as f64 / 1024.0,
        if bits == 4 { ", packed nibble + per-group scales" } else { ", int8" },
    );
    let stream: Vec<u16> = (0..4096).map(|_| rng.below(tier.vocab as u32) as u16).collect();
    let mut wl = Workload::poisson(&stream, n, rate, 8, 40, max_new, 7);
    // shared system prompt: the prefix-cache demo workload — every
    // request pays its prefill once, the rest hit the trie
    let shared_prefix = args.get_usize("shared-prefix", 0);
    if shared_prefix > 0 {
        let prefix: Vec<u16> =
            (0..shared_prefix).map(|_| rng.below(tier.vocab as u32) as u16).collect();
        for p in wl.prompts.iter_mut() {
            let mut with = prefix.clone();
            with.extend_from_slice(p);
            *p = with;
        }
    }

    let threads = args.get_usize("threads", 1);
    let kernel_backend = args.get("kernels").filter(|v| *v != "auto").map(|v| {
        KernelBackend::parse(v)
            .unwrap_or_else(|| panic!("--kernels {v}: unknown backend (auto|scalar|avx2|neon)"))
    });
    let kers = match kernel_backend {
        Some(b) => Kernels::for_backend(b),
        None => Kernels::auto(),
    };
    println!("int8 kernel dispatch: {} (override with --kernels / QUAMBA_KERNELS)", kers.label());
    let cache_bytes = args.get_mb("cache-mb", 0.0);
    let snapshot_stride = args.get_usize("snapshot-stride", 64);
    if cache_bytes > 0 {
        println!(
            "prefix cache: {:.1} MB budget, snapshot stride {snapshot_stride} \
             (tokens are bit-identical to --cache-mb 0)",
            cache_bytes as f64 / 1e6
        );
    }
    let prefill_chunk = args.get_usize("prefill-chunk", 64);
    let max_tokens_per_tick = args.get_usize("max-tokens-per-tick", 0);
    println!(
        "scheduler: prefill_chunk={prefill_chunk} max_tokens_per_tick={max_tokens_per_tick} \
         (0 = unchunked/unlimited; chunking moves latency, never tokens)"
    );
    let faults = fault_plan(args);
    // speculative decoding: each arm gets its own draft twin built
    // from the same weights (drafts are cheap — W4A8 twins share the
    // calibration stream, fp32 drafts regenerate from the seed)
    let spec_tokens = args.get_usize("spec-tokens", 0);
    let spec_draft = {
        let raw = args.get_or("spec-draft", "w4a8");
        SpecDraft::parse(raw)
            .unwrap_or_else(|| panic!("--spec-draft {raw}: expected w4a8 or fp32"))
    };
    let drafts: Vec<Option<Box<dyn StepModel + Send + Sync>>> = if spec_tokens == 0 {
        vec![None, None]
    } else {
        let mk = || -> Box<dyn StepModel + Send + Sync> {
            match spec_draft {
                SpecDraft::W4A8 => {
                    let qcfg = QuantConfig { weight_bits: 4, ..QuantConfig::default() };
                    Box::new(QuantizedMambaModel::from_model(&model, &calib, &qcfg))
                }
                SpecDraft::Fp32 => Box::new(MambaModel::synthetic(tier.clone(), seed)),
            }
        };
        println!(
            "speculative decoding: K={spec_tokens} draft={} \
             (tokens bit-identical to --spec-tokens 0, only throughput moves)",
            spec_draft.label()
        );
        vec![Some(mk()), Some(mk())]
    };
    let backends: Vec<(&str, u8, Box<dyn StepModel + Send + Sync>)> =
        vec![("fp32", 32, Box::new(model)), (qname, bits, Box::new(qmodel))];
    for ((name, wb, m), draft) in backends.into_iter().zip(drafts) {
        println!(
            "\n=== native {}/{name}: {n} requests, ~{rate}/s, {max_new} new tokens each ===",
            tier.name
        );
        let cfg = NativeEngineConfig {
            threads,
            kernel_backend,
            cache_bytes,
            snapshot_stride,
            prefill_chunk,
            max_tokens_per_tick,
            faults: faults.clone(),
            weight_bits: wb,
            trace: args.get("trace-out").is_some(),
            spec_tokens,
            spec_draft,
            ..Default::default()
        };
        let server = match draft {
            Some(d) => ServerHandle::spawn_native_with_draft(m, d, cfg)?,
            None => ServerHandle::spawn_native(m, cfg)?,
        };
        let (done, wall, report) = drive(server, &wl, max_new, args);
        println!("completed {done}/{n} in {wall:.2}s");
        if let Some(r) = report {
            println!("{r}");
        }
    }
    Ok(())
}

/// `--manual-clock MS`: the deterministic observability path. The
/// engine runs on [`Clock::Manual`] — every timestamp is ticks ×
/// MS, never a wall-clock read — with the flight recorder armed.
/// Requests are submitted up-front and the engine is driven to
/// completion on this thread, so two runs with the same seed produce
/// **byte-identical** `--trace-out` dumps and equal
/// [`MetricsSnapshot`]s (the determinism the obs integration tests
/// assert).
fn serve_manual_clock(args: &Args, tier: &MambaTier, n: usize, max_new: usize) -> Result<()> {
    use quamba::coordinator::request::Request;
    use quamba::coordinator::{Clock, NativeEngine};
    let ms_per_tick = args.get_f64("manual-clock", 1.0);
    let seed = args.get_usize("seed", 7) as u64;
    let bits = weight_bits(args);
    let model = MambaModel::synthetic(tier.clone(), seed);
    let mut rng = Pcg32::new(seed ^ 0x5EED);
    let calib: Vec<u16> = (0..512).map(|_| rng.below(tier.vocab as u32) as u16).collect();
    let qcfg = QuantConfig { weight_bits: bits, ..QuantConfig::default() };
    let qmodel = QuantizedMambaModel::from_model(&model, &calib, &qcfg);
    let cfg = NativeEngineConfig {
        weight_bits: bits,
        clock: Clock::Manual { ms_per_tick },
        trace: true,
        cache_bytes: args.get_mb("cache-mb", 0.0),
        snapshot_stride: args.get_usize("snapshot-stride", 64),
        prefill_chunk: args.get_usize("prefill-chunk", 64),
        max_tokens_per_tick: args.get_usize("max-tokens-per-tick", 0),
        ..Default::default()
    };
    println!(
        "manual clock: {ms_per_tick} ms/tick, {n} requests submitted up-front \
         (W{bits}A8, tier {}) — deterministic traces + snapshots",
        tier.name
    );
    let mut eng = NativeEngine::new(Box::new(qmodel), cfg);
    let stream: Vec<u16> = (0..4096).map(|_| rng.below(tier.vocab as u32) as u16).collect();
    let wl = Workload::poisson(&stream, n, 8.0, 8, 40, max_new, 7);
    for (i, prompt) in wl.prompts.iter().enumerate() {
        eng.submit(Request {
            id: (i + 1) as u64,
            prompt: prompt.clone(),
            max_new_tokens: max_new,
            params: SamplingParams::default(),
            stop_at_eos: false,
        });
    }
    let mut responses = eng.run_to_completion()?;
    responses.sort_by_key(|r| r.id);
    let snap = eng.metrics_snapshot();
    println!(
        "drained {} responses in {:.0} engine-ms ({} tokens)",
        responses.len(),
        snap.elapsed_ms,
        snap.tokens_out
    );
    if args.has("verbose") {
        for r in &responses {
            println!("{}", r.timeline());
        }
    }
    if let Some(path) = args.get("trace-out") {
        if let Some(json) = eng.dump_trace() {
            std::fs::write(path, &json)?;
            println!(
                "trace: wrote {} bytes of Chrome trace JSON to {path} \
                 (byte-identical run-to-run at a fixed seed)",
                json.len()
            );
        }
    }
    println!("\n{}", eng.metrics.report());
    Ok(())
}
