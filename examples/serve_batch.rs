//! Batched-serving scenario (the paper's "request-intensive cloud"
//! motivation): Poisson arrivals into the threaded server, continuous
//! bucketed decode batching, TTFT/TPOT/TTLT + throughput report,
//! FP vs Quamba side by side.
//!
//!     cargo run --release --example serve_batch -- [--requests 24] [--rate 8]

use anyhow::Result;
use quamba::bench_support::Workload;
use quamba::config::Manifest;
use quamba::coordinator::server::ServerHandle;
use quamba::coordinator::{EngineConfig, SamplingParams};
use quamba::data;
use quamba::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env(&[]);
    let root = Manifest::default_root();
    let mani = Manifest::load(&root).map_err(anyhow::Error::msg)?;
    // prefer the tier with wide decode buckets (m2p8 in the full build)
    let tier = args
        .get("tier")
        .map(String::from)
        .or_else(|| {
            mani.graphs
                .values()
                .filter(|g| g.kind == "decode" && g.batch > 1)
                .map(|g| g.tier.clone())
                .next()
        })
        .or_else(|| mani.tiers.keys().next().cloned())
        .expect("no artifacts");
    let n = args.get_usize("requests", 24);
    let rate = args.get_f64("rate", 8.0);
    let max_new = args.get_usize("max-new", 24);
    let stream = data::load_stream(&mani.data["pile_eval"])?;
    let wl = Workload::poisson(&stream, n, rate, 8, 40, max_new, 7);

    for method in ["fp16", "quamba"] {
        if !mani
            .graphs
            .values()
            .any(|g| g.tier == tier && g.method == method && g.kind == "decode")
        {
            continue;
        }
        println!("\n=== {tier}/{method}: {n} requests, ~{rate}/s, {max_new} new tokens each ===");
        let mut server = ServerHandle::spawn(root.clone(), EngineConfig::new(&tier, method))?;
        let t0 = std::time::Instant::now();
        let mut rxs = Vec::new();
        for (i, prompt) in wl.prompts.iter().enumerate() {
            let target = wl.arrival_s[i];
            let now = t0.elapsed().as_secs_f64();
            if target > now {
                std::thread::sleep(std::time::Duration::from_secs_f64(target - now));
            }
            rxs.push(server.submit(prompt.clone(), max_new, SamplingParams::default()));
        }
        let done = rxs.into_iter().filter(|rx| rx.recv().is_ok()).count();
        let wall = t0.elapsed().as_secs_f64();
        println!("completed {done}/{n} in {wall:.2}s");
        if let Some(r) = server.metrics_report() {
            println!("{r}");
        }
        server.shutdown();
    }
    Ok(())
}
