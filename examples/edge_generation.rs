//! Edge-deployment scenario (the paper's Orin-Nano story, §H/Fig. 9):
//! single-stream long generation under a tight memory budget. Prints a
//! live token stream for FP vs Quamba plus the TPOT trace and the
//! constant per-request state footprint.
//!
//!     cargo run --release --example edge_generation -- [--max-new 96]

use anyhow::Result;
use quamba::config::Manifest;
use quamba::coordinator::engine::{Engine, EngineConfig};
use quamba::coordinator::request::{Request, SamplingParams};
use quamba::data;
use quamba::runtime::Runtime;
use quamba::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env(&[]);
    let root = Manifest::default_root();
    let mani = Manifest::load(&root).map_err(anyhow::Error::msg)?;
    let tier = args
        .get("tier")
        .map(String::from)
        .or_else(|| mani.tiers.keys().filter(|t| *t != "jamba").last().cloned())
        .expect("no artifacts");
    let max_new = args.get_usize("max-new", 96);
    let stream = data::load_stream(&mani.data["pile_eval"])?;
    let vocab = data::Vocab::load(&mani.data["vocab"])?;
    let prompt = stream[100..132].to_vec();
    println!("tier {tier}; prompt: {}\n", vocab.decode(&prompt));

    for method in ["fp16", "quamba"] {
        let rt = Runtime::new(&root)?;
        let mut engine = match Engine::new(rt, EngineConfig::new(&tier, method)) {
            Ok(e) => e,
            Err(_) => continue,
        };
        engine.warmup()?;
        println!(
            "=== {method}: model {:.2} MB, per-request state {:.1} KB (constant) ===",
            mani.weights
                .get(&format!("{tier}_{method}"))
                .map(|w| w.bytes as f64 / 1e6)
                .unwrap_or(f64::NAN),
            engine.state_bytes_per_request() as f64 / 1024.0
        );
        engine.submit(Request {
            id: 1,
            prompt: prompt.clone(),
            max_new_tokens: max_new,
            params: SamplingParams { temperature: 0.7, top_k: 30, seed: 3, ..Default::default() },
            stop_at_eos: false,
        });
        let t0 = std::time::Instant::now();
        let responses = engine.run_to_completion()?;
        let resp = &responses[0];
        println!("{}", vocab.decode(&resp.tokens));
        println!(
            "\nTTFT {:.1} ms · TPOT mean {:.2} ms · {} tokens in {:.2}s · decode p99 {:.2} ms\n",
            resp.ttft_ms,
            resp.tpot_ms,
            resp.tokens.len(),
            t0.elapsed().as_secs_f64(),
            engine.metrics.decode_step_ms.quantile(0.99),
        );
    }
    Ok(())
}
