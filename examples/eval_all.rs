//! END-TO-END DRIVER (DESIGN.md deliverable (b)/EXPERIMENTS.md §E2E):
//! exercises every layer on a real small workload — loads the trained
//! tiers through PJRT, serves batched requests (throughput/latency),
//! evaluates perplexity on both corpora and the six-task suite for FP
//! vs Quamba, and prints the headline comparison the paper makes:
//! near-FP accuracy at roughly half the model bytes.
//!
//!     make artifacts && cargo run --release --example eval_all

use anyhow::Result;
use quamba::bench_support::{f2, pct, Table, Workload};
use quamba::config::Manifest;
use quamba::coordinator::server::ServerHandle;
use quamba::coordinator::{EngineConfig, SamplingParams};
use quamba::data::{load_stream, load_tasks};
use quamba::eval::{average_accuracy, perplexity, run_tasks};
use quamba::runtime::Runtime;

fn main() -> Result<()> {
    let root = Manifest::default_root();
    let mut rt = Runtime::new(&root)?;
    let tiers: Vec<String> = rt
        .manifest()
        .tiers
        .keys()
        .filter(|t| *t != "jamba")
        .cloned()
        .collect();
    let wiki = load_stream(&rt.manifest().data["wiki_eval"])?;
    let pile = load_stream(&rt.manifest().data["pile_eval"])?;
    let tasks = load_tasks(&rt.manifest().data["tasks"])?;

    // 1) accuracy: FP vs Quamba on every tier
    let mut t = Table::new(
        "End-to-end — FP32 vs Quamba W8A8 (perplexity / avg accuracy / bytes)",
        &["tier", "fp ppl(wiki)", "q ppl(wiki)", "fp ppl(pile)", "q ppl(pile)",
          "fp acc", "q acc", "size ratio"],
    );
    for tier in &tiers {
        let fp_w = perplexity(&mut rt, tier, "fp16", &wiki, 8).map(|r| r.ppl);
        let q_w = perplexity(&mut rt, tier, "quamba", &wiki, 8).map(|r| r.ppl);
        let fp_p = perplexity(&mut rt, tier, "fp16", &pile, 8).map(|r| r.ppl);
        let q_p = perplexity(&mut rt, tier, "quamba", &pile, 8).map(|r| r.ppl);
        let fp_a = run_tasks(&mut rt, tier, "fp16", &tasks, 30).map(|r| average_accuracy(&r));
        let q_a = run_tasks(&mut rt, tier, "quamba", &tasks, 30).map(|r| average_accuracy(&r));
        let ratio = match (
            rt.model_bytes(&format!("{tier}_fp16")),
            rt.model_bytes(&format!("{tier}_quamba")),
        ) {
            (Some(f), Some(q)) => format!("{:.2}x", f as f64 / q as f64),
            _ => "-".into(),
        };
        t.row(vec![
            tier.clone(),
            fp_w.map(f2).unwrap_or_default(),
            q_w.map(f2).unwrap_or_default(),
            fp_p.map(f2).unwrap_or_default(),
            q_p.map(f2).unwrap_or_default(),
            fp_a.map(pct).unwrap_or_default(),
            q_a.map(pct).unwrap_or_default(),
            ratio,
        ]);
    }
    t.print();
    drop(rt);

    // 2) serving: batched workload through the threaded coordinator
    let serve_tier = tiers.last().cloned().unwrap();
    let stream = load_stream(&Manifest::load(&root).map_err(anyhow::Error::msg)?.data["pile_eval"])?;
    let wl = Workload::poisson(&stream, 12, 20.0, 8, 32, 16, 99);
    for method in ["fp16", "quamba"] {
        let mani = Manifest::load(&root).map_err(anyhow::Error::msg)?;
        if !mani
            .graphs
            .values()
            .any(|g| g.tier == serve_tier && g.method == method && g.kind == "decode")
        {
            continue;
        }
        let mut server = ServerHandle::spawn(root.clone(), EngineConfig::new(&serve_tier, method))?;
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = wl
            .prompts
            .iter()
            .map(|p| server.submit(p.clone(), 16, SamplingParams::default()))
            .collect();
        let done = rxs.into_iter().filter(|rx| rx.recv().is_ok()).count();
        println!(
            "\nserving {serve_tier}/{method}: {done}/12 requests in {:.2}s",
            t0.elapsed().as_secs_f64()
        );
        if let Some(r) = server.metrics_report() {
            println!("{r}");
        }
        server.shutdown();
    }
    println!("\neval_all complete — see EXPERIMENTS.md for the recorded run.");
    Ok(())
}
