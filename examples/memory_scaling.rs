//! Memory-scaling scenario (paper Fig. 1(c)): drive both state pools —
//! the SSM's constant slabs and the transformer's growing KV cache —
//! through a simulated long-context serving session and print the
//! per-request + aggregate memory trajectory, including the KV pool's
//! backpressure watermark kicking in.
//!
//!     cargo run --release --example memory_scaling

use anyhow::Result;
use quamba::bench_support::Table;
use quamba::config::Manifest;
use quamba::coordinator::state::{KvCachePool, SsmStatePool};

fn main() -> Result<()> {
    let root = Manifest::default_root();
    let mani = Manifest::load(&root).map_err(anyhow::Error::msg)?;
    let tier = mani
        .tiers
        .values()
        .filter(|t| t.name != "jamba")
        .last()
        .expect("run `make artifacts`")
        .clone();

    let mut t = Table::new(
        "Per-request state while a conversation grows (KB)",
        &["context len", "mamba state", "pythia KV"],
    );
    let ssm = SsmStatePool::new(&tier, 8);
    let kv_tier = mani.transformer_tiers.values().next().cloned();
    for ctx in [64usize, 128, 256, 512, 1024, 2048] {
        let kv = kv_tier
            .as_ref()
            .map(|pt| {
                let pool = KvCachePool::new(pt, 1, usize::MAX);
                format!("{:.1}", pool.bytes_per_request(ctx) as f64 / 1024.0)
            })
            .unwrap_or_else(|| "-".into());
        t.row(vec![
            ctx.to_string(),
            format!("{:.1}", ssm.bytes_per_request() as f64 / 1024.0),
            kv,
        ]);
    }
    t.print();

    // aggregate: admit requests until the KV watermark rejects; the SSM
    // pool admits capacity-many regardless of context
    if let Some(pt) = kv_tier {
        let budget = 2 * 1024 * 1024; // 2 MB budget, edge-device flavored
        let mut kv = KvCachePool::new(&pt, 64, budget);
        let mut admitted = 0;
        while kv.alloc(512).is_some() {
            admitted += 1;
        }
        println!(
            "\nKV pool with a {budget} B budget admits {admitted} requests at ctx=512\n\
             (then backpressures); the SSM pool admits its full capacity at\n\
             {:.1} KB each regardless of context — the paper's Fig. 1(c) story.",
            ssm.bytes_per_request() as f64 / 1024.0
        );
    }
    Ok(())
}
