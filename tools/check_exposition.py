#!/usr/bin/env python3
"""Prometheus text-exposition (0.0.4) lint for the quamba `/metrics`
endpoint (rust/src/obs/exporter.rs).

Usage:
    python3 tools/check_exposition.py [FILE] [--require NAME[>MIN]]...

Reads the exposition body from FILE (or stdin) and validates:

* every sample line parses as `name{labels} value` with legal metric
  and label names and properly quoted label values;
* every sample's base metric carries a `# TYPE` declaration, and the
  declared type is one the exporter emits (counter/gauge/histogram);
* counters are non-negative and finite;
* for each histogram: `le` upper bounds strictly increase and end at
  `+Inf`, bucket counts are cumulative (non-decreasing), the `+Inf`
  bucket equals `_count`, and `_sum`/`_count` are present;
* `--require NAME` fails unless a sample of NAME exists;
  `--require NAME>MIN` additionally demands some sample value > MIN
  (how the CI smoke asserts traffic actually flowed).

Exit code 0 = clean, 1 = findings (each printed as `exposition: ...`),
2 = usage/IO error. Stdlib only; importable (`validate(text)` returns
the findings list) so tools/metrics_smoke.py reuses the checks.
"""

import re
import sys

METRIC_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")
# one label pair: name="value" with \\ \" \n escapes allowed in value
PAIR_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')
KNOWN_TYPES = {"counter", "gauge", "histogram", "summary", "untyped"}
HIST_SUFFIXES = ("_bucket", "_sum", "_count")


def parse_value(tok):
    """Prometheus sample value: decimal/scientific, +Inf/-Inf/NaN."""
    if tok == "+Inf":
        return float("inf")
    if tok == "-Inf":
        return float("-inf")
    try:
        return float(tok)
    except ValueError:
        return None


def parse_sample(line):
    """Return (name, labels-dict, value) or None if unparseable."""
    m = re.match(r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{.*\})?\s+(\S+)$", line)
    if not m:
        return None
    name, labelblob, valtok = m.group(1), m.group(2), m.group(3)
    labels = {}
    if labelblob:
        # strict sequential scan: pairs only, separated by commas — any
        # leading/interstitial junk makes the whole sample malformed
        body = labelblob[1:-1]
        pos = 0
        while pos < len(body):
            pm = PAIR_RE.match(body, pos)
            if not pm:
                return None
            labels[pm.group(1)] = pm.group(2)
            pos = pm.end()
            if pos < len(body):
                if body[pos] != ",":
                    return None
                pos += 1
    value = parse_value(valtok)
    if value is None:
        return None
    return name, labels, value


def base_name(name, types):
    """Strip the histogram sample suffix when the base is a histogram."""
    for suf in HIST_SUFFIXES:
        if name.endswith(suf) and types.get(name[: -len(suf)]) == "histogram":
            return name[: -len(suf)]
    return name


def validate(text, require=()):
    """Lint an exposition body; returns a list of finding strings."""
    findings = []
    types = {}
    helps = set()
    samples = []  # (lineno, name, labels, value)
    for i, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line:
            continue
        if line.startswith("# HELP "):
            parts = line.split(None, 3)
            if len(parts) < 3 or not METRIC_RE.match(parts[2]):
                findings.append(f"line {i}: malformed HELP: {raw!r}")
            else:
                helps.add(parts[2])
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4 or not METRIC_RE.match(parts[2]):
                findings.append(f"line {i}: malformed TYPE: {raw!r}")
            elif parts[3] not in KNOWN_TYPES:
                findings.append(f"line {i}: unknown type {parts[3]!r}")
            elif parts[2] in types:
                findings.append(f"line {i}: duplicate TYPE for {parts[2]}")
            else:
                types[parts[2]] = parts[3]
            continue
        if line.startswith("#"):
            continue  # plain comment
        s = parse_sample(line)
        if s is None:
            findings.append(f"line {i}: unparseable sample: {raw!r}")
            continue
        name, labels, value = s
        for ln in labels:
            if not LABEL_RE.match(ln):
                findings.append(f"line {i}: bad label name {ln!r}")
        samples.append((i, name, labels, value))

    by_base = {}
    for i, name, labels, value in samples:
        base = base_name(name, types)
        if base not in types:
            findings.append(f"line {i}: sample {name} has no # TYPE declaration")
            continue
        by_base.setdefault(base, []).append((i, name, labels, value))
        if types[base] == "counter" and not (value >= 0 and value != float("inf")):
            findings.append(f"line {i}: counter {name} = {value} (must be finite, >= 0)")

    for base, rows in sorted(by_base.items()):
        if types.get(base) != "histogram":
            continue
        # group buckets by their non-`le` label set: one series each
        series = {}
        sums, counts = {}, {}
        for i, name, labels, value in rows:
            key = tuple(sorted((k, v) for k, v in labels.items() if k != "le"))
            if name == base + "_bucket":
                if "le" not in labels:
                    findings.append(f"line {i}: {name} without le label")
                    continue
                le = parse_value(labels["le"])
                if le is None:
                    findings.append(f"line {i}: {name} has non-numeric le={labels['le']!r}")
                    continue
                series.setdefault(key, []).append((i, le, value))
            elif name == base + "_sum":
                sums[key] = (i, value)
            elif name == base + "_count":
                counts[key] = (i, value)
        for key, buckets in series.items():
            les = [le for _, le, _ in buckets]
            if sorted(les) != les or len(set(les)) != len(les):
                findings.append(f"{base}: le bounds not strictly increasing: {les}")
            if not les or les[-1] != float("inf"):
                findings.append(f"{base}: bucket series does not end at le=\"+Inf\"")
            prev = -1.0
            for i, le, c in buckets:
                if c < prev:
                    findings.append(
                        f"line {i}: {base}_bucket counts not cumulative ({c} < {prev})"
                    )
                prev = c
            if key not in counts:
                findings.append(f"{base}: missing _count for series {dict(key)}")
            elif buckets and buckets[-1][1] == float("inf") and buckets[-1][2] != counts[key][1]:
                findings.append(
                    f"{base}: +Inf bucket {buckets[-1][2]} != _count {counts[key][1]}"
                )
            if key not in sums:
                findings.append(f"{base}: missing _sum for series {dict(key)}")

    for req in require:
        if ">" in req:
            name, minval = req.split(">", 1)
            minval = float(minval)
        else:
            name, minval = req, None
        hits = [v for _, n, _, v in samples if n == name]
        if not hits:
            findings.append(f"required metric {name} has no samples")
        elif minval is not None and not any(v > minval for v in hits):
            findings.append(f"required metric {name} <= {minval} (samples: {hits})")
    return findings


def main(argv):
    args = argv[1:]
    require = []
    path = None
    i = 0
    while i < len(args):
        if args[i] == "--require":
            if i + 1 >= len(args):
                print(__doc__)
                return 2
            require.append(args[i + 1])
            i += 2
        elif args[i] in ("-h", "--help"):
            print(__doc__)
            return 0
        elif path is None:
            path = args[i]
            i += 1
        else:
            print(__doc__)
            return 2
    try:
        text = sys.stdin.read() if path in (None, "-") else open(path).read()
    except OSError as e:
        print(f"exposition: cannot read {path}: {e}")
        return 2
    findings = validate(text, require)
    for f in findings:
        print(f"exposition: {f}")
    if not findings:
        n = len([l for l in text.splitlines() if l and not l.startswith("#")])
        print(f"exposition: clean ({n} samples)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
