#!/usr/bin/env python3
"""Perf-trajectory gate: diff a fresh BENCH_native_decode.json against
the committed baseline and emit warnings for per-op regressions.

Usage:
    python3 tools/bench_diff.py BASELINE.json FRESH.json \
        [--warn-pct 25] [--latency-warn-pct 50]

Entries are matched by (op, shape). A fresh entry whose `ms` is more
than --warn-pct percent above the baseline produces a GitHub Actions
`::warning::` annotation (the step itself stays green: shared-runner
timing noise must not block merges — the annotations make the
trajectory visible in the PR checks instead). Exit code is 0 unless a
file is unreadable/malformed.

Serving-latency keys (ops prefixed ttft_/itl_/burst_ — TTFT p50,
pooled ITL p95, and the chunked-prefill burst max-gap pair) are
end-to-end wall-clock quantities and noisier than the per-op
microbenches, so they get their own, laxer --latency-warn-pct budget
(warning-only, same as everything else).

The committed baseline starts out `"provisional": true` (this repo's
build toolchain lives outside the container that authored it); the
first CI run on real hardware prints a refresh instruction. To refresh:
copy a trusted run's BENCH_native_decode.json over the baseline file.
"""

import json
import sys

# ops carrying end-to-end serving latency rather than per-op kernel time
LATENCY_PREFIXES = ("ttft_", "itl_", "burst_")

# ops whose `ms` field is a count, not a time (e.g. accept_len_mean,
# the speculative-decoding mean acceptance length): printed in the
# table for trajectory, but a higher value is better or neutral, so
# they are exempt from the regression budget entirely
COUNT_PREFIXES = ("accept_len_",)


def load(path):
    with open(path) as f:
        doc = json.load(f)
    entries = {}
    for e in doc.get("entries", []):
        # tolerate newly-added or partial entries: a bench revision may
        # introduce ops with extra fields, or placeholder rows without
        # timings yet (e.g. a provisional baseline listing expected
        # keys). Skip what can't be compared instead of erroring — the
        # gate's job is trajectory, not schema enforcement.
        if not isinstance(e, dict) or "op" not in e or "shape" not in e:
            print(f"bench_diff: skipping malformed entry in {path}: {e!r}")
            continue
        if not isinstance(e.get("ms"), (int, float)):
            # a provisional baseline lists expected keys without
            # timings on purpose — stay quiet about those
            if not doc.get("provisional"):
                print(f"bench_diff: skipping {e['op']} [{e['shape']}] in {path}: no ms value")
            continue
        entries[(e["op"], e["shape"])] = e
    return doc, entries


def main(argv):
    if len(argv) < 3:
        print(__doc__)
        return 2
    warn_pct = 25.0
    if "--warn-pct" in argv:
        warn_pct = float(argv[argv.index("--warn-pct") + 1])
    latency_warn_pct = 50.0
    if "--latency-warn-pct" in argv:
        latency_warn_pct = float(argv[argv.index("--latency-warn-pct") + 1])
    try:
        base_doc, base = load(argv[1])
        _, fresh = load(argv[2])
    except (OSError, ValueError, KeyError) as e:
        print(f"bench_diff: cannot read inputs: {e}")
        return 2

    common = sorted(set(base) & set(fresh))
    if base_doc.get("provisional") or not common:
        print(
            "bench_diff: baseline is provisional/empty — no gating this run.\n"
            f"To arm the gate, refresh the baseline from a trusted run:\n"
            f"    cp {argv[2]} {argv[1]}"
        )
        return 0

    regressions = 0
    print(f"{'op':<28} {'shape':<34} {'base ms':>10} {'fresh ms':>10} {'delta':>8}")
    for key in common:
        b, f = base[key]["ms"], fresh[key]["ms"]
        delta = (f - b) / b * 100.0 if b > 0 else 0.0
        # serving-latency keys are end-to-end wall clock → laxer budget
        is_latency = key[0].startswith(LATENCY_PREFIXES)
        budget = latency_warn_pct if is_latency else warn_pct
        flag = ""
        if key[0].startswith(COUNT_PREFIXES):
            # counts (acceptance length etc.): trajectory display only
            print(f"{key[0]:<28} {key[1]:<34} {b:>10.4f} {f:>10.4f} {delta:>+7.1f}%  (count)")
            continue
        if delta > budget:
            regressions += 1
            flag = "  <-- REGRESSION"
            kind = "serving-latency regression" if is_latency else "perf regression"
            print(
                f"::warning title={kind}::{key[0]} [{key[1]}] "
                f"{b:.4f}ms -> {f:.4f}ms (+{delta:.1f}% > {budget:.0f}%)"
            )
        print(f"{key[0]:<28} {key[1]:<34} {b:>10.4f} {f:>10.4f} {delta:>+7.1f}%{flag}")
    only_base = sorted(set(base) - set(fresh))
    only_fresh = sorted(set(fresh) - set(base))
    if only_base:
        print(f"bench_diff: {len(only_base)} baseline op(s) missing from fresh run: {only_base}")
    if only_fresh:
        print(f"bench_diff: {len(only_fresh)} new op(s) not in baseline yet: {only_fresh}")
    print(
        f"bench_diff: {len(common)} ops compared, {regressions} regression(s) "
        f"over the {warn_pct:.0f}% budget"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
