#!/usr/bin/env python3
"""CI smoke for the /metrics exporter: launch `quamba serve --backend
native` on a synthetic tier with an ephemeral metrics port, scrape the
live endpoint over real HTTP while the server lingers, and lint the
exposition body with tools/check_exposition.py.

Usage:
    python3 tools/metrics_smoke.py [--bin "cargo run --release --"]

`--bin` is split shell-style, so it takes either a binary path
(`target/release/quamba`) or a cargo invocation (the default — reuses
the build cache the tier-1 step warmed).

Flow:
  1. spawn `quamba serve --backend native --requests 8 --max-new 8
     --rate 1000 --metrics-port 0 --metrics-linger-ms 15000`
     (ephemeral port; the linger keeps the exporter up after the
     workload drains so the scrape can't race the shutdown);
  2. parse "metrics: listening on http://127.0.0.1:PORT/metrics"
     from its stdout;
  3. poll the endpoint until a 200 scrape reports
     quamba_tokens_generated_total > 0 and 8 done requests;
  4. validate the final body with check_exposition.validate()
     (format lint + histogram cumulativity + required series);
  5. also assert non-/metrics paths 404.

Exit 0 on success; non-zero with the reason (and the server's output)
on any failure. Stdlib only.
"""

import argparse
import os
import re
import shlex
import signal
import subprocess
import sys
import threading
import time
import urllib.error
import urllib.request

import check_exposition

PORT_RE = re.compile(r"metrics: listening on http://127\.0\.0\.1:(\d+)/metrics")


def pump(stream, sink):
    for line in iter(stream.readline, ""):
        sink.append(line)
    stream.close()


def scrape(port, path="/metrics", timeout=2.0):
    """Return (status, body) for one HTTP GET; raises on socket errors."""
    url = f"http://127.0.0.1:{port}{path}"
    try:
        with urllib.request.urlopen(url, timeout=timeout) as r:
            return r.status, r.read().decode("utf-8", "replace")
    except urllib.error.HTTPError as e:
        return e.code, e.read().decode("utf-8", "replace")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--bin",
        default="cargo run --release --",
        help="quamba binary path or cargo invocation (split shell-style)",
    )
    ap.add_argument("--timeout-s", type=float, default=300.0)
    args = ap.parse_args()

    cmd = shlex.split(args.bin) + [
        "serve", "--backend", "native",
        "--requests", "8", "--max-new", "8", "--rate", "1000",
        "--metrics-port", "0", "--metrics-linger-ms", "15000",
    ]
    print("metrics-smoke:", " ".join(cmd))
    # own process group: `cargo run` wraps the real server, so signal
    # the whole group or the grandchild would outlive a kill
    proc = subprocess.Popen(
        cmd,
        stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT,
        text=True,
        start_new_session=True,
    )
    lines = []
    t = threading.Thread(target=pump, args=(proc.stdout, lines), daemon=True)
    t.start()

    def stop(sig):
        try:
            os.killpg(os.getpgid(proc.pid), sig)
        except (ProcessLookupError, PermissionError):
            pass

    def fail(reason):
        stop(signal.SIGKILL)
        t.join(timeout=5)
        print(f"metrics-smoke: FAIL — {reason}")
        print("---- server output ----")
        sys.stdout.write("".join(lines))
        return 1

    deadline = time.time() + args.timeout_s
    port = None
    while port is None:
        for line in lines:
            m = PORT_RE.search(line)
            if m:
                port = int(m.group(1))
                break
        if port is None:
            if proc.poll() is not None:
                return fail("server exited before announcing the metrics port")
            if time.time() > deadline:
                return fail("timed out waiting for the metrics-port banner")
            time.sleep(0.1)
    print(f"metrics-smoke: exporter on port {port}")

    # poll until the workload has drained into the counters (the linger
    # window guarantees the endpoint outlives the last response)
    body = None
    while True:
        if time.time() > deadline:
            return fail("timed out waiting for a scrape showing 8 done requests")
        try:
            status, text = scrape(port)
        except OSError:
            time.sleep(0.2)
            continue
        if status == 200:
            body = text
            done = re.search(r'quamba_requests_total\{[^}]*outcome="done"[^}]*\} (\d+)', text)
            toks = re.search(r"quamba_tokens_generated_total\{[^}]*\} (\d+)", text)
            if done and int(done.group(1)) >= 8 and toks and int(toks.group(1)) > 0:
                print(
                    f"metrics-smoke: scrape shows {done.group(1)} done requests, "
                    f"{toks.group(1)} tokens"
                )
                break
        if proc.poll() is not None:
            return fail(f"server exited (rc={proc.returncode}) before a full scrape")
        time.sleep(0.2)

    findings = check_exposition.validate(
        body,
        require=[
            "quamba_tokens_generated_total>0",
            "quamba_requests_total",
            "quamba_ttft_ms_bucket",
            "quamba_itl_ms_quantile",
            "quamba_tick_ms_count>0",
            "quamba_queue_depth_count",
        ],
    )
    if findings:
        for f in findings:
            print(f"metrics-smoke: exposition: {f}")
        return fail(f"{len(findings)} exposition finding(s)")
    print(f"metrics-smoke: exposition clean ({len(body.splitlines())} lines)")

    try:
        status, _ = scrape(port, path="/nope")
        if status != 404:
            return fail(f"GET /nope answered {status}, expected 404")
    except OSError as e:
        return fail(f"404 probe failed: {e}")
    print("metrics-smoke: non-/metrics path 404s as documented")

    # done validating — no need to sit out the linger window
    stop(signal.SIGTERM)
    t.join(timeout=10)
    print("metrics-smoke: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
